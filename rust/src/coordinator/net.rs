//! Readiness-based TCP front end (`MMEE_NET=epoll`): a Linux
//! edge-triggered epoll event loop that serves the exact wire protocol
//! of [`crate::coordinator::service`] without a thread per connection.
//!
//! ## Why
//!
//! The thread-per-connection front end pins one pool worker for the
//! whole life of a connection — an *idle* keep-alive client costs a
//! blocked thread, and tail latency collapses once connections
//! outnumber the pool. Here a connection costs a few hundred bytes of
//! state: N event-loop threads (`MMEE_NET_LOOPS`, default 2) multiplex
//! every socket, decode requests in place, and hand them to `workers`
//! plan threads through the same bounded queue discipline the rest of
//! the stack uses. Thread count is `loops + workers`, independent of
//! connection count.
//!
//! ## Mechanics
//!
//! * **Raw syscalls, zero dependencies** — `epoll_create1` /
//!   `epoll_ctl` / `epoll_wait` / `eventfd` are declared `extern "C"`
//!   against libc (which std already links); sockets stay ordinary
//!   nonblocking [`std::net::TcpStream`]s, so all the actual I/O goes
//!   through std's vetted read/`write_vectored` paths.
//! * **Listener sharing** — every loop registers the listener
//!   level-triggered with `EPOLLEXCLUSIVE`, so the kernel wakes ONE
//!   loop per pending connection instead of thundering all of them.
//! * **Connection state machines** — each connection owns a grow-only
//!   read buffer framed in place (newline scan over the buffer; no
//!   per-request `String` on the hot path), a pipeline window
//!   (backpressure: at most [`MAX_INFLIGHT`] undecided requests per
//!   connection), a reorder map that restores request order however
//!   the plan workers finish, and a write queue flushed with vectored
//!   writes under `EPOLLOUT` backpressure.
//! * **Edge-triggered discipline** — conn sockets are registered once
//!   with `EPOLLIN | EPOLLOUT | EPOLLRDHUP | EPOLLET` (no per-event
//!   `EPOLL_CTL_MOD` churn): reads always drain to `WouldBlock`, and
//!   writes are attempted eagerly after every enqueue so a pending
//!   `EPOLLOUT` edge is only ever *needed* after a genuine
//!   `WouldBlock`.
//! * **eventfd wakeups** — plan workers push completions into the
//!   owning loop's mailbox and write the loop's `eventfd`; the loop
//!   re-arms writers when it wakes. No spinning, no wake pipes per
//!   connection.
//! * **Deadlines/priorities/overload ride through unchanged** —
//!   requests are parsed at framing time (so `deadline_ms` starts
//!   counting while the request waits in the plan queue, exactly as
//!   documented), and a full plan queue answers with the same
//!   structured `overloaded` error the threads front end uses — per
//!   *request* here, since no connection needs shedding when
//!   connections are cheap.
//! * **Graceful drain** — once `max_conns` connections have been
//!   accepted (or accept fails), every loop deregisters the listener,
//!   keeps serving until each remaining connection has reached EOF
//!   with every response flushed, and only then closes. Zero accepted
//!   requests are ever dropped.
//!
//! Non-Linux builds fall back to the threads front end (the wire
//! bytes are identical either way); [`NetMode::resolved`] is the one
//! place that decides.

/// Which connection front end [`crate::coordinator::service::serve_tcp`]
/// uses. Selected by `MMEE_NET` (`threads` | `epoll`), default
/// `threads`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum NetMode {
    /// Thread-per-connection pool (the portable default).
    Threads,
    /// Edge-triggered epoll event loops (Linux only).
    Epoll,
}

impl NetMode {
    /// Wire/metrics name (`metrics.net` reports this).
    pub fn name(self) -> &'static str {
        match self {
            NetMode::Threads => "threads",
            NetMode::Epoll => "epoll",
        }
    }

    pub fn parse(s: &str) -> Option<NetMode> {
        match s.trim().to_ascii_lowercase().as_str() {
            "threads" | "thread" => Some(NetMode::Threads),
            "epoll" => Some(NetMode::Epoll),
            _ => None,
        }
    }

    /// Read `MMEE_NET`. Deliberately re-read on every server start (no
    /// `OnceLock`): one process can host both front ends — the A/B
    /// bench and the equivalence tests do. Unknown values fall back to
    /// `threads` with a note on stderr.
    pub fn from_env() -> NetMode {
        match std::env::var("MMEE_NET") {
            Err(_) => NetMode::Threads,
            Ok(v) => NetMode::parse(&v).unwrap_or_else(|| {
                eprintln!(
                    "mmee serve: unknown MMEE_NET='{v}' (want threads|epoll), using threads"
                );
                NetMode::Threads
            }),
        }
    }

    /// Can `Epoll` run on this build target?
    pub fn epoll_supported() -> bool {
        cfg!(target_os = "linux")
    }

    /// Downgrade `Epoll` to `Threads` off-Linux. The wire protocol is
    /// byte-identical either way, so this is an implementation swap,
    /// not a behavior change.
    pub fn resolved(self) -> NetMode {
        if self == NetMode::Epoll && !NetMode::epoll_supported() {
            eprintln!("mmee serve: MMEE_NET=epoll needs Linux, using the threads front end");
            return NetMode::Threads;
        }
        self
    }
}

/// Per-connection pipeline window: at most this many requests may be
/// in flight or reordering per connection before framing pauses (the
/// unread bytes simply stay in the connection's buffer — TCP
/// backpressure does the rest).
pub const MAX_INFLIGHT: usize = 64;

#[cfg(target_os = "linux")]
pub(crate) use linux::serve_epoll;

/// Stub for non-Linux targets. Unreachable through [`serve_tcp`]
/// (`NetMode::resolved` downgrades first); callers holding a raw
/// `NetMode::Epoll` get a structured error.
///
/// [`serve_tcp`]: crate::coordinator::service::serve_tcp
#[cfg(not(target_os = "linux"))]
pub(crate) fn serve_epoll(
    _engine: &crate::search::MmeeEngine,
    _listener: std::net::TcpListener,
    _max_conns: Option<usize>,
    _workers: usize,
    _metrics: &crate::coordinator::service::ServiceMetrics,
) -> std::io::Result<usize> {
    Err(std::io::Error::new(
        std::io::ErrorKind::Unsupported,
        "MMEE_NET=epoll requires Linux (use MMEE_NET=threads)",
    ))
}

#[cfg(target_os = "linux")]
mod linux {
    use std::collections::{BTreeMap, HashMap, VecDeque};
    use std::io::{self, IoSlice, Read, Write};
    use std::net::{TcpListener, TcpStream};
    use std::os::fd::AsRawFd;
    use std::os::raw::{c_int, c_uint, c_void};
    use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
    use std::sync::Mutex;
    use std::time::Instant;

    use super::MAX_INFLIGHT;
    use crate::coordinator::pool::{BoundedQueue, PushError};
    use crate::coordinator::service::{self, OpClass, Request, Response, ServiceMetrics};
    use crate::error::MmeeError;
    use crate::search::MmeeEngine;

    // ---- raw epoll/eventfd FFI (libc is already linked by std) ----

    /// `struct epoll_event`; packed on x86_64 (the kernel ABI).
    #[repr(C)]
    #[cfg_attr(target_arch = "x86_64", repr(packed))]
    #[derive(Clone, Copy)]
    struct EpollEvent {
        events: u32,
        data: u64,
    }

    extern "C" {
        fn epoll_create1(flags: c_int) -> c_int;
        fn epoll_ctl(epfd: c_int, op: c_int, fd: c_int, event: *mut EpollEvent) -> c_int;
        fn epoll_wait(
            epfd: c_int,
            events: *mut EpollEvent,
            maxevents: c_int,
            timeout_ms: c_int,
        ) -> c_int;
        fn eventfd(initval: c_uint, flags: c_int) -> c_int;
        fn read(fd: c_int, buf: *mut c_void, count: usize) -> isize;
        fn write(fd: c_int, buf: *const c_void, count: usize) -> isize;
        fn close(fd: c_int) -> c_int;
    }

    const EPOLL_CLOEXEC: c_int = 0o2000000;
    const EPOLL_CTL_ADD: c_int = 1;
    const EPOLL_CTL_DEL: c_int = 2;
    const EPOLLIN: u32 = 0x001;
    const EPOLLOUT: u32 = 0x004;
    const EPOLLERR: u32 = 0x008;
    const EPOLLHUP: u32 = 0x010;
    const EPOLLRDHUP: u32 = 0x2000;
    const EPOLLEXCLUSIVE: u32 = 1 << 28;
    const EPOLLET: u32 = 1 << 31;
    const EFD_CLOEXEC: c_int = 0o2000000;
    const EFD_NONBLOCK: c_int = 0o4000;

    /// Owned raw fd (epoll instances and eventfds; sockets stay inside
    /// std types). Closed on drop — which only happens when
    /// `serve_epoll`'s scope is fully joined, so a worker's late wake
    /// can never hit a recycled fd number.
    struct Fd(c_int);

    impl Drop for Fd {
        fn drop(&mut self) {
            let _ = unsafe { close(self.0) };
        }
    }

    fn cvt(ret: c_int) -> io::Result<c_int> {
        if ret < 0 {
            Err(io::Error::last_os_error())
        } else {
            Ok(ret)
        }
    }

    fn ep_add(ep: c_int, fd: c_int, events: u32, token: u64) -> io::Result<()> {
        let mut ev = EpollEvent { events, data: token };
        cvt(unsafe { epoll_ctl(ep, EPOLL_CTL_ADD, fd, &mut ev) }).map(|_| ())
    }

    fn ep_del(ep: c_int, fd: c_int) -> io::Result<()> {
        // A dummy event: pre-2.6.9 kernels reject a null pointer.
        let mut ev = EpollEvent { events: 0, data: 0 };
        cvt(unsafe { epoll_ctl(ep, EPOLL_CTL_DEL, fd, &mut ev) }).map(|_| ())
    }

    const TOKEN_LISTENER: u64 = 0;
    const TOKEN_WAKE: u64 = 1;
    const FIRST_CONN_TOKEN: u64 = 2;

    /// Socket buffers per vectored write.
    const MAX_IOV: usize = 16;
    const READ_CHUNK: usize = 4096;

    /// Event loops per epoll server: `MMEE_NET_LOOPS`, default 2,
    /// clamped to 1..=16. Two loops saturate the framing side long
    /// before the plan workers saturate; more only helps at extreme
    /// accept/framing rates.
    fn event_loops() -> usize {
        std::env::var("MMEE_NET_LOOPS")
            .ok()
            .and_then(|v| v.trim().parse::<usize>().ok())
            .map(|n| n.clamp(1, 16))
            .unwrap_or(2)
    }

    /// A finished response on its way back to the owning event loop.
    struct Completion {
        token: u64,
        seq: u64,
        line: String,
        requests: usize,
    }

    /// A decoded request headed for the plan workers.
    struct Job {
        loop_id: usize,
        token: u64,
        seq: u64,
        req: Request,
        t0: Instant,
    }

    /// One event loop's kernel handles + completion mailbox.
    struct LoopShared {
        ep: Fd,
        wake: Fd,
        completions: Mutex<Vec<Completion>>,
    }

    impl LoopShared {
        /// Signal the loop's eventfd. Failure is benign: the loop has
        /// either already been woken or is already draining the
        /// mailbox.
        fn wake(&self) {
            let one: u64 = 1;
            let _ = unsafe { write(self.wake.0, &one as *const u64 as *const c_void, 8) };
        }
    }

    struct Ctx<'a> {
        engine: &'a MmeeEngine,
        metrics: &'a ServiceMetrics,
        listener: TcpListener,
        listener_fd: c_int,
        max_conns: Option<usize>,
        loops: Vec<LoopShared>,
        queue: BoundedQueue<Job>,
        accepted: AtomicUsize,
        served: AtomicUsize,
        draining: AtomicBool,
        accept_err: Mutex<Option<io::Error>>,
        next_token: AtomicU64,
    }

    impl Ctx<'_> {
        /// Stop accepting everywhere: set the flag and wake every loop
        /// so each deregisters the listener and starts its drain.
        fn start_drain(&self) {
            self.draining.store(true, Ordering::SeqCst);
            for l in &self.loops {
                l.wake();
            }
        }

        fn note_accept_err(&self, e: io::Error) {
            self.accept_err.lock().unwrap_or_else(|p| p.into_inner()).get_or_insert(e);
        }
    }

    /// Per-connection state machine. Owned by exactly one event loop;
    /// plan workers only ever see the decoded [`Request`]s.
    struct Conn {
        stream: TcpStream,
        /// Grow-only read buffer; bytes `parsed..rlen` are unframed.
        rbuf: Vec<u8>,
        rlen: usize,
        parsed: usize,
        /// Next request seq to assign / next response seq to emit.
        next_seq: u64,
        next_write: u64,
        /// Out-of-order completions: seq -> (line, requests answered).
        ready: BTreeMap<u64, (String, usize)>,
        /// Wire bytes awaiting the socket; head partially written.
        wq: VecDeque<Vec<u8>>,
        wq_head: usize,
        /// Requests at the plan workers.
        inflight: usize,
        /// Mirrors the metrics busy gauge (idle = open - busy).
        busy: bool,
        eof: bool,
        dead: bool,
    }

    impl Conn {
        fn new(stream: TcpStream) -> Conn {
            Conn {
                stream,
                rbuf: vec![0; READ_CHUNK],
                rlen: 0,
                parsed: 0,
                next_seq: 0,
                next_write: 0,
                ready: BTreeMap::new(),
                wq: VecDeque::new(),
                wq_head: 0,
                inflight: 0,
                busy: false,
                eof: false,
                dead: false,
            }
        }

        /// Make room to read: reclaim the consumed prefix first, and
        /// only grow when one line genuinely exceeds the buffer.
        fn make_room(&mut self) {
            if self.parsed > 0 {
                self.rbuf.copy_within(self.parsed..self.rlen, 0);
                self.rlen -= self.parsed;
                self.parsed = 0;
            }
            if self.rlen == self.rbuf.len() {
                let doubled = self.rbuf.len().max(READ_CHUNK / 2) * 2;
                self.rbuf.resize(doubled, 0);
            }
        }

        fn pipeline_full(&self) -> bool {
            self.inflight + self.ready.len() >= MAX_INFLIGHT
        }
    }

    /// Serve the epoll front end until drain completes. Returns
    /// requests served (batch lines count each element; per-request
    /// `overloaded` rejections count zero, matching the threads front
    /// end's accounting for shed work).
    pub(crate) fn serve_epoll(
        engine: &MmeeEngine,
        listener: TcpListener,
        max_conns: Option<usize>,
        workers: usize,
        metrics: &ServiceMetrics,
    ) -> io::Result<usize> {
        listener.set_nonblocking(true)?;
        let listener_fd = listener.as_raw_fd();
        let nloops = event_loops();
        let mut loops = Vec::with_capacity(nloops);
        for _ in 0..nloops {
            let ep = Fd(cvt(unsafe { epoll_create1(EPOLL_CLOEXEC) })?);
            let wake = Fd(cvt(unsafe { eventfd(0, EFD_CLOEXEC | EFD_NONBLOCK) })?);
            // The wake channel is level-triggered: a completion pushed
            // while the loop is busy stays visible at the next wait.
            ep_add(ep.0, wake.0, EPOLLIN, TOKEN_WAKE)?;
            ep_add(ep.0, listener_fd, EPOLLIN | EPOLLEXCLUSIVE, TOKEN_LISTENER)?;
            loops.push(LoopShared { ep, wake, completions: Mutex::new(Vec::new()) });
        }
        let ctx = Ctx {
            engine,
            metrics,
            listener,
            listener_fd,
            max_conns,
            loops,
            queue: BoundedQueue::new((workers * 2).max(4)),
            accepted: AtomicUsize::new(0),
            served: AtomicUsize::new(0),
            draining: AtomicBool::new(false),
            accept_err: Mutex::new(None),
            next_token: AtomicU64::new(FIRST_CONN_TOKEN),
        };
        if ctx.max_conns == Some(0) {
            ctx.start_drain();
        }
        let mut loop_panic = false;
        std::thread::scope(|scope| {
            let ctx = &ctx;
            for _ in 0..workers {
                scope.spawn(move || worker_loop(ctx));
            }
            let handles: Vec<_> =
                (0..nloops).map(|i| scope.spawn(move || run_loop(ctx, i))).collect();
            for h in handles {
                loop_panic |= h.join().is_err();
            }
            // Every loop has drained its connections: nothing pushes
            // jobs anymore; release the plan workers.
            ctx.queue.close();
        });
        if let Some(e) = ctx.accept_err.lock().unwrap_or_else(|p| p.into_inner()).take() {
            return Err(e);
        }
        if loop_panic {
            return Err(io::Error::other("epoll event loop panicked"));
        }
        Ok(ctx.served.load(Ordering::Relaxed))
    }

    /// Plan worker: pop decoded requests, plan them on the shared
    /// engine, mail the response back to the owning loop and ring its
    /// eventfd.
    fn worker_loop(ctx: &Ctx<'_>) {
        while let Some(job) = ctx.queue.pop() {
            ctx.metrics.set_queue_depth(ctx.queue.len());
            let resp = service::handle_metered(ctx.engine, ctx.metrics, &job.req);
            let requests = resp.count();
            ctx.metrics.record(OpClass::of(&job.req), job.t0.elapsed(), &resp);
            let target = &ctx.loops[job.loop_id];
            target
                .completions
                .lock()
                .unwrap_or_else(|p| p.into_inner())
                .push(Completion { token: job.token, seq: job.seq, line: resp.to_line(), requests });
            target.wake();
        }
    }

    /// One event loop: wait, dispatch, repeat until drained.
    fn run_loop(ctx: &Ctx<'_>, me: usize) {
        let ls = &ctx.loops[me];
        let mut conns: HashMap<u64, Conn> = HashMap::new();
        let mut events = vec![EpollEvent { events: 0, data: 0 }; 256];
        let mut accepting = true;
        loop {
            if ctx.draining.load(Ordering::SeqCst) {
                if accepting {
                    // EPOLLEXCLUSIVE forbids MOD but allows DEL.
                    let _ = ep_del(ls.ep.0, ctx.listener_fd);
                    accepting = false;
                }
                deliver_completions(ctx, me, &mut conns);
                if conns.is_empty() {
                    return;
                }
            }
            let n = unsafe {
                epoll_wait(ls.ep.0, events.as_mut_ptr(), events.len() as c_int, -1)
            };
            if n < 0 {
                let e = io::Error::last_os_error();
                if e.kind() == io::ErrorKind::Interrupted {
                    continue;
                }
                ctx.note_accept_err(e);
                ctx.start_drain();
                continue;
            }
            for ev in &events[..n as usize] {
                let (token, bits) = (ev.data, ev.events);
                match token {
                    TOKEN_LISTENER => accept_ready(ctx, me, &mut conns, accepting),
                    TOKEN_WAKE => {
                        drain_eventfd(ls.wake.0);
                        deliver_completions(ctx, me, &mut conns);
                    }
                    _ => conn_event(ctx, me, &mut conns, token, bits),
                }
            }
        }
    }

    fn drain_eventfd(fd: c_int) {
        let mut counter: u64 = 0;
        // One read zeroes the (nonblocking) counter.
        let _ = unsafe { read(fd, &mut counter as *mut u64 as *mut c_void, 8) };
    }

    /// Accept until `WouldBlock` (or drain starts). Level-triggered +
    /// `EPOLLEXCLUSIVE` means pending connections re-notify some loop
    /// even if this one stops early.
    fn accept_ready(ctx: &Ctx<'_>, me: usize, conns: &mut HashMap<u64, Conn>, accepting: bool) {
        if !accepting {
            return;
        }
        while !ctx.draining.load(Ordering::SeqCst) {
            match ctx.listener.accept() {
                Ok((stream, _)) => {
                    let total = ctx.accepted.fetch_add(1, Ordering::SeqCst) + 1;
                    register_conn(ctx, me, conns, stream);
                    if ctx.max_conns.is_some_and(|m| total >= m) {
                        ctx.start_drain();
                    }
                }
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
                Err(e) => {
                    // Fatal accept error: report it and drain, exactly
                    // like the threads front end's accept loop.
                    ctx.note_accept_err(e);
                    ctx.start_drain();
                    break;
                }
            }
        }
    }

    fn register_conn(ctx: &Ctx<'_>, me: usize, conns: &mut HashMap<u64, Conn>, s: TcpStream) {
        if s.set_nonblocking(true).is_err() {
            return;
        }
        let _ = s.set_nodelay(true);
        let token = ctx.next_token.fetch_add(1, Ordering::Relaxed);
        let fd = s.as_raw_fd();
        ctx.metrics.conn_accepted();
        // Registered ONCE, edge-triggered, with both directions armed:
        // the kernel reports current readiness as the first edge, so
        // bytes that raced ahead of the ADD are not lost.
        let flags = EPOLLIN | EPOLLOUT | EPOLLRDHUP | EPOLLET;
        if ep_add(ctx.loops[me].ep.0, fd, flags, token).is_err() {
            ctx.metrics.conn_closed();
            return;
        }
        conns.insert(token, Conn::new(s));
    }

    /// Dispatch one readiness event for a connection, then reap it if
    /// it finished or died.
    fn conn_event(ctx: &Ctx<'_>, me: usize, conns: &mut HashMap<u64, Conn>, token: u64, bits: u32) {
        if let Some(conn) = conns.get_mut(&token) {
            if bits & (EPOLLERR | EPOLLHUP) != 0 {
                conn.dead = true;
            }
            if !conn.dead && bits & (EPOLLIN | EPOLLRDHUP) != 0 {
                read_ready(ctx, me, token, conn);
            }
            if !conn.dead && bits & EPOLLOUT != 0 {
                flush_writes(conn);
            }
        }
        maybe_remove(ctx, conns, token);
    }

    /// Drain the socket to `WouldBlock` (edge-triggered contract),
    /// then frame and dispatch whatever arrived.
    fn read_ready(ctx: &Ctx<'_>, me: usize, token: u64, conn: &mut Conn) {
        loop {
            if conn.rlen == conn.rbuf.len() {
                conn.make_room();
            }
            match (&conn.stream).read(&mut conn.rbuf[conn.rlen..]) {
                Ok(0) => {
                    conn.eof = true;
                    break;
                }
                Ok(n) => conn.rlen += n,
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
                Err(_) => {
                    conn.dead = true;
                    return;
                }
            }
        }
        pump_conn(ctx, me, token, conn);
    }

    /// Frame → dispatch → order → write: the whole per-connection
    /// pipeline, run after reads and after completion deliveries.
    fn pump_conn(ctx: &Ctx<'_>, me: usize, token: u64, conn: &mut Conn) {
        frame_requests(ctx, me, token, conn);
        flush_ready(ctx, conn);
        flush_writes(conn);
    }

    /// Parse one framed line (borrowing the read buffer in place).
    /// `None` for blank lines.
    fn parse_slice(raw: &[u8]) -> Option<Result<Request, MmeeError>> {
        let raw = raw.trim_ascii();
        if raw.is_empty() {
            return None;
        }
        Some(match std::str::from_utf8(raw) {
            Ok(s) => Request::parse(s),
            Err(_) => Err(MmeeError::Parse("request line is not valid UTF-8".into())),
        })
    }

    /// Frame complete lines out of the read buffer and dispatch each,
    /// bounded by the pipeline window. Zero-copy: requests are parsed
    /// straight out of `rbuf`; only the decoded [`Request`] travels.
    fn frame_requests(ctx: &Ctx<'_>, me: usize, token: u64, conn: &mut Conn) {
        while !conn.pipeline_full() {
            let window = &conn.rbuf[conn.parsed..conn.rlen];
            let Some(pos) = window.iter().position(|&b| b == b'\n') else {
                break;
            };
            let start = conn.parsed;
            conn.parsed = start + pos + 1;
            let parsed = parse_slice(&conn.rbuf[start..start + pos]);
            if let Some(p) = parsed {
                submit(ctx, me, token, conn, p);
            }
        }
        // A final unterminated line becomes a request at EOF —
        // `BufRead::lines` on the threads path does the same.
        if conn.eof && !conn.pipeline_full() && conn.parsed < conn.rlen {
            let tail = &conn.rbuf[conn.parsed..conn.rlen];
            if !tail.contains(&b'\n') {
                let parsed = parse_slice(tail);
                conn.parsed = conn.rlen;
                if let Some(p) = parsed {
                    submit(ctx, me, token, conn, p);
                }
            }
        }
        if conn.parsed == conn.rlen {
            // Everything framed: rewind so the buffer never grows for
            // well-behaved clients.
            conn.parsed = 0;
            conn.rlen = 0;
        }
    }

    /// Route one parsed request: control ops and parse errors answer
    /// on the loop thread; mapping work goes to the plan workers with
    /// per-request overload shedding.
    fn submit(
        ctx: &Ctx<'_>,
        me: usize,
        token: u64,
        conn: &mut Conn,
        parsed: Result<Request, MmeeError>,
    ) {
        let seq = conn.next_seq;
        conn.next_seq += 1;
        let t0 = Instant::now();
        match parsed {
            Err(e) => {
                let resp = Response::Error(e);
                ctx.metrics.record(OpClass::Plan, t0.elapsed(), &resp);
                conn.ready.insert(seq, (resp.to_line(), 1));
            }
            Ok(req @ Request::Control(_)) => {
                // Cheap and latency-sensitive: answered inline so a
                // metrics/ping probe never queues behind plan work.
                let resp = service::handle_metered(ctx.engine, ctx.metrics, &req);
                let requests = resp.count();
                ctx.metrics.record(OpClass::Control, t0.elapsed(), &resp);
                conn.ready.insert(seq, (resp.to_line(), requests));
            }
            Ok(req) => {
                match ctx.queue.try_push(Job { loop_id: me, token, seq, req, t0 }) {
                    Ok(()) => {
                        conn.inflight += 1;
                        ctx.metrics.set_queue_depth(ctx.queue.len());
                        if !conn.busy {
                            conn.busy = true;
                            ctx.metrics.conn_busy(true);
                        }
                    }
                    Err(PushError::Full(job)) => {
                        // Same structured rejection the threads front
                        // end sheds with — per request, not per
                        // connection, because connections are cheap
                        // here. Counts zero toward `served`, matching
                        // the threads path's shed accounting.
                        let err = MmeeError::Overloaded { pending: ctx.queue.len() };
                        let resp = Response::Error(err);
                        ctx.metrics.record(OpClass::of(&job.req), job.t0.elapsed(), &resp);
                        conn.ready.insert(seq, (resp.to_line(), 0));
                    }
                    Err(PushError::Closed(_)) => {
                        let resp = Response::Error(MmeeError::Io("server draining".into()));
                        conn.ready.insert(seq, (resp.to_line(), 0));
                    }
                }
            }
        }
    }

    /// Move completed responses into the write queue in request order.
    fn flush_ready(ctx: &Ctx<'_>, conn: &mut Conn) {
        while let Some((line, requests)) = conn.ready.remove(&conn.next_write) {
            conn.next_write += 1;
            ctx.served.fetch_add(requests, Ordering::Relaxed);
            let mut bytes = line.into_bytes();
            bytes.push(b'\n');
            conn.wq.push_back(bytes);
        }
        if conn.inflight == 0 && conn.busy {
            conn.busy = false;
            ctx.metrics.conn_busy(false);
        }
    }

    /// Vectored-write the queue until empty or `WouldBlock`. Always
    /// attempted eagerly after enqueue — an `EPOLLOUT` edge is only
    /// relied on after a genuine `WouldBlock`, which is exactly when
    /// the kernel guarantees one.
    fn flush_writes(conn: &mut Conn) {
        while !conn.wq.is_empty() {
            let mut iov: Vec<IoSlice<'_>> = Vec::with_capacity(conn.wq.len().min(MAX_IOV));
            for (i, buf) in conn.wq.iter().take(MAX_IOV).enumerate() {
                let slice = if i == 0 { &buf[conn.wq_head..] } else { &buf[..] };
                iov.push(IoSlice::new(slice));
            }
            match (&conn.stream).write_vectored(&iov) {
                Ok(0) => {
                    conn.dead = true;
                    return;
                }
                Ok(mut n) => {
                    while n > 0 {
                        let head_left = conn.wq[0].len() - conn.wq_head;
                        if n >= head_left {
                            n -= head_left;
                            conn.wq.pop_front();
                            conn.wq_head = 0;
                        } else {
                            conn.wq_head += n;
                            n = 0;
                        }
                    }
                }
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => return,
                Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
                Err(_) => {
                    conn.dead = true;
                    return;
                }
            }
        }
    }

    /// Deliver the mailbox: hand each completion to its connection's
    /// reorder map, then pump every touched connection (framing may
    /// resume now that pipeline room opened).
    fn deliver_completions(ctx: &Ctx<'_>, me: usize, conns: &mut HashMap<u64, Conn>) {
        let batch = std::mem::take(
            &mut *ctx.loops[me].completions.lock().unwrap_or_else(|p| p.into_inner()),
        );
        if batch.is_empty() {
            return;
        }
        let mut touched: Vec<u64> = Vec::with_capacity(batch.len());
        for c in batch {
            // The connection may have died while its request was in
            // flight; its completion is simply dropped.
            if let Some(conn) = conns.get_mut(&c.token) {
                conn.inflight -= 1;
                conn.ready.insert(c.seq, (c.line, c.requests));
                touched.push(c.token);
            }
        }
        touched.sort_unstable();
        touched.dedup();
        for token in touched {
            if let Some(conn) = conns.get_mut(&token) {
                pump_conn(ctx, me, token, conn);
            }
            maybe_remove(ctx, conns, token);
        }
    }

    /// Reap a connection that died, or finished cleanly: EOF seen,
    /// every framed request answered, every byte flushed. Dropping the
    /// `TcpStream` closes the fd, which the kernel auto-deregisters
    /// from epoll.
    fn maybe_remove(ctx: &Ctx<'_>, conns: &mut HashMap<u64, Conn>, token: u64) {
        let Some(conn) = conns.get(&token) else {
            return;
        };
        let finished = conn.eof
            && conn.inflight == 0
            && conn.ready.is_empty()
            && conn.wq.is_empty()
            && conn.parsed == conn.rlen;
        if conn.dead || finished {
            let conn = conns.remove(&token).expect("checked above");
            if conn.busy {
                ctx.metrics.conn_busy(false);
            }
            ctx.metrics.conn_closed();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::NetMode;

    #[test]
    fn mode_parsing_and_names() {
        assert_eq!(NetMode::parse("epoll"), Some(NetMode::Epoll));
        assert_eq!(NetMode::parse(" THREADS "), Some(NetMode::Threads));
        assert_eq!(NetMode::parse("thread"), Some(NetMode::Threads));
        assert_eq!(NetMode::parse("uring"), None);
        assert_eq!(NetMode::Epoll.name(), "epoll");
        assert_eq!(NetMode::Threads.name(), "threads");
        // `resolved` is the identity on Linux and a downgrade elsewhere.
        let r = NetMode::Epoll.resolved();
        if NetMode::epoll_supported() {
            assert_eq!(r, NetMode::Epoll);
        } else {
            assert_eq!(r, NetMode::Threads);
        }
        assert_eq!(NetMode::Threads.resolved(), NetMode::Threads);
    }
}
