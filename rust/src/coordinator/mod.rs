//! L3 coordination substrate: the thread pool that parallelizes surface
//! evaluation and the request-service loop (`mmee serve`).
//!
//! Built from std primitives — no tokio/rayon in the offline build; the
//! pool is part of the system's substrate inventory (DESIGN.md §5).

pub mod pool;
pub mod service;

pub use pool::parallel_chunks;
pub use service::{serve_lines, Request, Response};
