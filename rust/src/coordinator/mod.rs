//! L3 coordination substrate: the thread pools that parallelize surface
//! evaluation and the request-service loops (`mmee serve`).
//!
//! Built from std primitives — no tokio/rayon in the offline build; the
//! pool is part of the system's substrate inventory (DESIGN.md §5).
//! [`pool`] provides chunked data-parallelism (`parallel_chunks`) plus
//! the bounded-queue/sequencer pair behind the concurrent serving
//! loops; [`service`] speaks the JSON-lines wire format (single
//! requests and batch arrays) over stdin or TCP.

pub mod pool;
pub mod service;

pub use pool::{parallel_chunks, BoundedQueue, Sequencer};
pub use service::{serve_lines, serve_lines_concurrent, serve_tcp, Request, Response};
