//! L3 coordination substrate: the thread pools that parallelize surface
//! evaluation and the request-service loops (`mmee serve`).
//!
//! Built from std primitives — no tokio/rayon in the offline build; the
//! pool is part of the system's substrate inventory (DESIGN.md §5).
//! [`pool`] provides the persistent work-stealing [`EvalPool`] behind
//! every surface pass (with the `parallel_chunks` / [`pool::run_indexed`]
//! shims) plus the bounded-queue/sequencer pair behind the concurrent
//! serving loops; [`service`] speaks the JSON-lines wire format (single
//! requests and batch arrays) over stdin or TCP; [`net`] is the
//! readiness-based (epoll) TCP front end selected with `MMEE_NET=epoll`,
//! which serves the same wire bytes without a thread per connection.

pub mod net;
pub mod pool;
pub mod service;

pub use net::NetMode;
pub use pool::{
    parallel_chunks, run_indexed, run_indexed_cancellable, BoundedQueue, CancelToken, EvalPool,
    FillBuf, PushError, Sequencer,
};
pub use service::{
    handle_metered, metrics_json, serve_lines, serve_lines_concurrent, serve_tcp, serve_tcp_with,
    Control, Request, Response, ServiceMetrics,
};
