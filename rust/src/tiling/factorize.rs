//! Integer factorization helpers with memoization.
//!
//! Tiling enumeration is the dominant *online* cost of MMEE (paper
//! §VII-H: runtime is dominated by integer factorization and scales
//! ∝ n^0.4); divisor lists are cached per dimension value.

use std::collections::HashMap;
use std::sync::Mutex;
use std::sync::OnceLock;

/// Sorted divisors of `n` (ascending).
pub fn divisors(n: usize) -> Vec<usize> {
    assert!(n > 0);
    static CACHE: OnceLock<Mutex<HashMap<usize, Vec<usize>>>> = OnceLock::new();
    let cache = CACHE.get_or_init(|| Mutex::new(HashMap::new()));
    if let Some(d) = cache.lock().unwrap().get(&n) {
        return d.clone();
    }
    let mut small = Vec::new();
    let mut large = Vec::new();
    let mut d = 1;
    while d * d <= n {
        if n % d == 0 {
            small.push(d);
            if d * d != n {
                large.push(n / d);
            }
        }
        d += 1;
    }
    large.reverse();
    small.extend(large);
    cache.lock().unwrap().insert(n, small.clone());
    small
}

/// All ordered pairs `(x_D, x_G)` with `x_D · x_G = n`.
pub fn factor_pairs(n: usize) -> Vec<(usize, usize)> {
    divisors(n).into_iter().map(|d| (d, n / d)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn divisors_of_12() {
        assert_eq!(divisors(12), vec![1, 2, 3, 4, 6, 12]);
        assert_eq!(divisors(1), vec![1]);
        assert_eq!(divisors(64).len(), 7);
        assert_eq!(divisors(4096).len(), 13);
    }

    #[test]
    fn pairs_multiply_back() {
        for n in [1usize, 7, 36, 100, 4096] {
            for (a, b) in factor_pairs(n) {
                assert_eq!(a * b, n);
            }
        }
    }

    #[test]
    fn prop_divisor_list_complete_and_sorted() {
        prop::quick(
            128,
            0xD17,
            |rng, size| rng.range(1, size * 50),
            |&n| {
                let ds = divisors(n);
                for w in ds.windows(2) {
                    if w[0] >= w[1] {
                        return Err(format!("not sorted for {n}"));
                    }
                }
                for d in 1..=n {
                    let is_div = n % d == 0;
                    if is_div != ds.contains(&d) {
                        return Err(format!("divisor set wrong at {d} for {n}"));
                    }
                }
                Ok(())
            },
        );
    }
}
