//! Integer factorization helpers with memoization.
//!
//! Tiling enumeration is the dominant *online* cost of MMEE (paper
//! §VII-H: runtime is dominated by integer factorization and scales
//! ∝ n^0.4); divisor lists are cached per dimension value. The cache
//! hands out `Arc<[usize]>` so hits are a refcount bump, not a clone,
//! and each call takes the table lock exactly once.

use std::collections::HashMap;
use std::sync::Arc;
use std::sync::Mutex;
use std::sync::OnceLock;

/// Sorted divisors of `n` (ascending), shared out of a global memo
/// table. Hits clone only the `Arc`; the lock is acquired once per
/// call (misses compute the list while holding it — trial division up
/// to √n is far cheaper than a second lock round-trip per call on the
/// enumeration hot path).
pub fn divisors(n: usize) -> Arc<[usize]> {
    assert!(n > 0);
    static CACHE: OnceLock<Mutex<HashMap<usize, Arc<[usize]>>>> = OnceLock::new();
    let cache = CACHE.get_or_init(|| Mutex::new(HashMap::new()));
    let mut table = cache.lock().unwrap();
    if let Some(d) = table.get(&n) {
        return Arc::clone(d);
    }
    let mut small = Vec::new();
    let mut large = Vec::new();
    let mut d = 1;
    while d * d <= n {
        if n % d == 0 {
            small.push(d);
            if d * d != n {
                large.push(n / d);
            }
        }
        d += 1;
    }
    large.reverse();
    small.extend(large);
    let list: Arc<[usize]> = small.into();
    table.insert(n, Arc::clone(&list));
    list
}

/// All ordered pairs `(x_D, x_G)` with `x_D · x_G = n`, ascending in
/// `x_D` (hence descending in `x_G`) — the enumeration's lexicographic
/// visit order per dimension, and the monotonicity the fused builder's
/// capacity pruning relies on ([`crate::tiling::feasible_from`]).
pub fn factor_pairs(n: usize) -> Vec<(usize, usize)> {
    factor_pairs_cached(n).to_vec()
}

/// [`factor_pairs`] out of a global memo table (same policy as
/// [`divisors`]): the cold surface-construction path asks for the same
/// per-dimension pair lists on every build, so hits are a refcount
/// bump instead of a fresh `Vec`.
pub fn factor_pairs_cached(n: usize) -> Arc<[(usize, usize)]> {
    assert!(n > 0);
    static CACHE: OnceLock<Mutex<HashMap<usize, Arc<[(usize, usize)]>>>> = OnceLock::new();
    let cache = CACHE.get_or_init(|| Mutex::new(HashMap::new()));
    let mut table = cache.lock().unwrap();
    if let Some(p) = table.get(&n) {
        return Arc::clone(p);
    }
    let list: Arc<[(usize, usize)]> =
        divisors(n).iter().map(|&d| (d, n / d)).collect::<Vec<_>>().into();
    table.insert(n, Arc::clone(&list));
    list
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn divisors_of_12() {
        assert_eq!(&*divisors(12), &[1, 2, 3, 4, 6, 12]);
        assert_eq!(&*divisors(1), &[1]);
        assert_eq!(divisors(64).len(), 7);
        assert_eq!(divisors(4096).len(), 13);
    }

    #[test]
    fn repeat_lookups_share_one_allocation() {
        let a = divisors(360);
        let b = divisors(360);
        assert!(Arc::ptr_eq(&a, &b), "cache hit must share, not clone");
    }

    #[test]
    fn pairs_multiply_back() {
        for n in [1usize, 7, 36, 100, 4096] {
            for (a, b) in factor_pairs(n) {
                assert_eq!(a * b, n);
            }
        }
    }

    #[test]
    fn cached_pairs_share_one_allocation_and_order() {
        let a = factor_pairs_cached(720);
        let b = factor_pairs_cached(720);
        assert!(Arc::ptr_eq(&a, &b), "cache hit must share, not clone");
        assert_eq!(&*a, factor_pairs(720).as_slice());
        // Ascending x_D, descending x_G (the pruning precondition).
        for w in a.windows(2) {
            assert!(w[0].0 < w[1].0 && w[0].1 > w[1].1);
        }
    }

    #[test]
    fn prop_divisor_list_complete_and_sorted() {
        prop::quick(
            128,
            0xD17,
            |rng, size| rng.range(1, size * 50),
            |&n| {
                let ds = divisors(n);
                for w in ds.windows(2) {
                    if w[0] >= w[1] {
                        return Err(format!("not sorted for {n}"));
                    }
                }
                for d in 1..=n {
                    let is_div = n % d == 0;
                    if is_div != ds.contains(&d) {
                        return Err(format!("divisor set wrong at {d} for {n}"));
                    }
                }
                Ok(())
            },
        );
    }
}
