//! Online tiling enumeration (paper Fig. 12 right branch).
//!
//! Tile sizes are integer factorizations of the workload dimensions:
//! `X = x_D · x_G`. All divisor pairs of each dimension are enumerated
//! and crossed; a cheap footprint prefilter drops tilings whose minimal
//! working set can never fit the buffer.
//!
//! Two facts about [`min_footprint`] carry the fused surface builder
//! ([`crate::encode::build`]):
//!
//! * it is **monotone increasing in every granule** `x_G[d]`, and the
//!   per-dimension pair lists ([`factor_pairs`]) are granule-
//!   *descending* — so within any level of the lexicographic sweep the
//!   capacity-infeasible entries form a **prefix** of the iteration,
//!   binary-searchable with [`feasible_from`], and a whole inner
//!   subtree can be skipped the moment the partial bound (chosen outer
//!   granules + minimal remaining granules, i.e. 1) exceeds capacity;
//! * its arithmetic is **exact**: all terms are integers, and for
//!   dimensions below 2²⁵ every product stays below 2⁵⁰ and the
//!   5-term sum below 2⁵³, so `f64` introduces no rounding and the
//!   monotone/prefix structure holds bit-for-bit against the
//!   per-tiling reference test. (Survivor *membership* is robust even
//!   beyond that bound — both paths evaluate the identical
//!   [`min_footprint`] — but the binary-searchability of the prefix
//!   relies on this exactness, so don't reorder the sum.)
//!
//! [`enumerate_tilings`] is the retained serial reference: the serving
//! path builds tilings and feature columns in one fused pass instead
//! (see `encode::build`), property-tested byte-identical to this
//! enumeration followed by `BoundaryMatrix::build`.

pub mod factorize;

pub use factorize::{divisors, factor_pairs};

use crate::config::workload::FusedGemm;

/// One concrete tiling: inter-tile counts `xd` and granule sizes `xg`
/// per dimension `[i, k, l, j]`, with `xd[d] * xg[d] = dim[d]`.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq, Hash)]
pub struct Tiling {
    pub xd: [usize; 4],
    pub xg: [usize; 4],
}

impl Tiling {
    /// The untiled mapping (one giant tile per dimension).
    pub fn unit(g: &FusedGemm) -> Tiling {
        Tiling { xd: [1; 4], xg: g.dims() }
    }

    pub fn name(&self) -> String {
        format!(
            "i{}x{} k{}x{} l{}x{} j{}x{}",
            self.xd[0], self.xg[0], self.xd[1], self.xg[1],
            self.xd[2], self.xg[2], self.xd[3], self.xg[3]
        )
    }
}

/// Enumerate every tiling of a fused GEMM, optionally prefiltered by a
/// lower bound on the on-chip working set: any fused mapping needs at
/// least one granule tile of A, B, C, D and E simultaneously
/// (`min_footprint`), so tilings exceeding `capacity_words` are dropped
/// before evaluation. `capacity_words = None` disables the prefilter.
pub fn enumerate_tilings(g: &FusedGemm, capacity_words: Option<f64>) -> Vec<Tiling> {
    let fi = factor_pairs(g.i);
    let fk = factor_pairs(g.k);
    let fl = factor_pairs(g.l);
    let fj = factor_pairs(g.j);
    let mut out = Vec::with_capacity(fi.len() * fk.len() * fl.len() * fj.len());
    for &(id, ig) in &fi {
        for &(kd, kg) in &fk {
            for &(ld, lg) in &fl {
                for &(jd, jg) in &fj {
                    let t = Tiling { xd: [id, kd, ld, jd], xg: [ig, kg, lg, jg] };
                    if let Some(cap) = capacity_words {
                        if min_footprint(&t) > cap {
                            continue;
                        }
                    }
                    out.push(t);
                }
            }
        }
    }
    out
}

/// Lower bound on any mapping's working set for this tiling: one granule
/// of each operand (C's granule is the i×l tile it must fully hold).
/// Monotone increasing in every granule, and exact in `f64` for all
/// dimensions below 2²⁵ (see the module docs — the pruning path's
/// binary search relies on this, so keep the sum in this form).
pub fn min_footprint(t: &Tiling) -> f64 {
    let [ig, kg, lg, jg] = [t.xg[0] as f64, t.xg[1] as f64, t.xg[2] as f64, t.xg[3] as f64];
    ig * kg + kg * lg + ig * lg + lg * jg + ig * jg
}

/// First index in `pairs` (divisor-ascending, hence granule-descending)
/// at which substituting the pair's granule into dimension `d` of
/// `base` passes the capacity prefilter (`min_footprint ≤ cap`). The
/// footprint is monotone in `x_G[d]`, so the infeasible entries form a
/// prefix and the boundary is found by binary search — the subtree-
/// pruning primitive of the fused builder. Set the not-yet-chosen
/// dimensions of `base` to granule 1 (always achievable: `x_D = n`) to
/// lower-bound a whole subtree; returns `pairs.len()` when no entry is
/// feasible (the subtree can be skipped outright).
pub fn feasible_from(pairs: &[(usize, usize)], d: usize, base: &Tiling, cap: f64) -> usize {
    pairs.partition_point(|&(_, xg)| {
        let mut t = *base;
        t.xg[d] = xg;
        min_footprint(&t) > cap
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn unit_tiling() {
        let g = FusedGemm { i: 512, k: 64, l: 512, j: 64 };
        let t = Tiling::unit(&g);
        assert_eq!(t.xd, [1, 1, 1, 1]);
        assert_eq!(t.xg, [512, 64, 512, 64]);
    }

    #[test]
    fn enumeration_counts_match_divisor_products() {
        let g = FusedGemm { i: 16, k: 4, l: 8, j: 4 };
        let tilings = enumerate_tilings(&g, None);
        assert_eq!(
            tilings.len(),
            divisors(16).len() * divisors(4).len() * divisors(8).len() * divisors(4).len()
        );
    }

    #[test]
    fn every_tiling_factors_exactly() {
        let g = FusedGemm { i: 48, k: 6, l: 20, j: 9 };
        for t in enumerate_tilings(&g, None) {
            assert_eq!(t.xd[0] * t.xg[0], 48);
            assert_eq!(t.xd[1] * t.xg[1], 6);
            assert_eq!(t.xd[2] * t.xg[2], 20);
            assert_eq!(t.xd[3] * t.xg[3], 9);
        }
    }

    #[test]
    fn prefilter_only_drops_infeasible() {
        let g = FusedGemm { i: 64, k: 16, l: 64, j: 16 };
        let all = enumerate_tilings(&g, None);
        let cap = 4096.0;
        let kept = enumerate_tilings(&g, Some(cap));
        assert!(kept.len() < all.len());
        for t in &all {
            let keep = min_footprint(t) <= cap;
            assert_eq!(kept.contains(t), keep, "tiling {t:?}");
        }
    }

    #[test]
    fn prop_feasible_from_matches_linear_scan() {
        prop::quick(
            128,
            0xB5EA,
            |rng, size| {
                let s = size.max(2);
                let n = rng.range(1, 16 * s);
                let d = rng.below(4);
                let base = Tiling {
                    xd: [1; 4],
                    xg: [rng.range(1, s), rng.range(1, s), rng.range(1, s), rng.range(1, s)],
                };
                let cap = rng.range(1, 8 * s * s) as f64;
                (n, d, base, cap)
            },
            |&(n, d, base, cap)| {
                let pairs = factor_pairs(n);
                let got = feasible_from(&pairs, d, &base, cap);
                // Linear reference: first pair whose substituted tiling
                // passes the per-tiling prefilter test.
                let want = pairs
                    .iter()
                    .position(|&(_, xg)| {
                        let mut t = base;
                        t.xg[d] = xg;
                        min_footprint(&t) <= cap
                    })
                    .unwrap_or(pairs.len());
                if got != want {
                    return Err(format!("suffix start {got} != linear {want}"));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn prop_min_footprint_positive_and_monotone_in_granules() {
        prop::quick(
            64,
            0xF00D,
            |rng, size| {
                let s = size.max(2);
                Tiling {
                    xd: [1; 4],
                    xg: [
                        rng.range(1, s),
                        rng.range(1, s),
                        rng.range(1, s),
                        rng.range(1, s),
                    ],
                }
            },
            |t| {
                let f = min_footprint(t);
                if f <= 0.0 {
                    return Err("non-positive footprint".into());
                }
                let mut bigger = *t;
                bigger.xg[0] *= 2;
                if min_footprint(&bigger) <= f {
                    return Err("not monotone in i_g".into());
                }
                Ok(())
            },
        );
    }
}
