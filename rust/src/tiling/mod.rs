//! Online tiling enumeration (paper Fig. 12 right branch).
//!
//! Tile sizes are integer factorizations of the workload dimensions:
//! `X = x_D · x_G`. All divisor pairs of each dimension are enumerated
//! and crossed; a cheap footprint prefilter drops tilings whose minimal
//! working set can never fit the buffer.

pub mod factorize;

pub use factorize::{divisors, factor_pairs};

use crate::config::workload::FusedGemm;

/// One concrete tiling: inter-tile counts `xd` and granule sizes `xg`
/// per dimension `[i, k, l, j]`, with `xd[d] * xg[d] = dim[d]`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Tiling {
    pub xd: [usize; 4],
    pub xg: [usize; 4],
}

impl Tiling {
    /// The untiled mapping (one giant tile per dimension).
    pub fn unit(g: &FusedGemm) -> Tiling {
        Tiling { xd: [1; 4], xg: g.dims() }
    }

    pub fn name(&self) -> String {
        format!(
            "i{}x{} k{}x{} l{}x{} j{}x{}",
            self.xd[0], self.xg[0], self.xd[1], self.xg[1],
            self.xd[2], self.xg[2], self.xd[3], self.xg[3]
        )
    }
}

/// Enumerate every tiling of a fused GEMM, optionally prefiltered by a
/// lower bound on the on-chip working set: any fused mapping needs at
/// least one granule tile of A, B, C, D and E simultaneously
/// (`min_footprint`), so tilings exceeding `capacity_words` are dropped
/// before evaluation. `capacity_words = None` disables the prefilter.
pub fn enumerate_tilings(g: &FusedGemm, capacity_words: Option<f64>) -> Vec<Tiling> {
    let fi = factor_pairs(g.i);
    let fk = factor_pairs(g.k);
    let fl = factor_pairs(g.l);
    let fj = factor_pairs(g.j);
    let mut out = Vec::with_capacity(fi.len() * fk.len() * fl.len() * fj.len());
    for &(id, ig) in &fi {
        for &(kd, kg) in &fk {
            for &(ld, lg) in &fl {
                for &(jd, jg) in &fj {
                    let t = Tiling { xd: [id, kd, ld, jd], xg: [ig, kg, lg, jg] };
                    if let Some(cap) = capacity_words {
                        if min_footprint(&t) > cap {
                            continue;
                        }
                    }
                    out.push(t);
                }
            }
        }
    }
    out
}

/// Lower bound on any mapping's working set for this tiling: one granule
/// of each operand (C's granule is the i×l tile it must fully hold).
pub fn min_footprint(t: &Tiling) -> f64 {
    let [ig, kg, lg, jg] = [t.xg[0] as f64, t.xg[1] as f64, t.xg[2] as f64, t.xg[3] as f64];
    ig * kg + kg * lg + ig * lg + lg * jg + ig * jg
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn unit_tiling() {
        let g = FusedGemm { i: 512, k: 64, l: 512, j: 64 };
        let t = Tiling::unit(&g);
        assert_eq!(t.xd, [1, 1, 1, 1]);
        assert_eq!(t.xg, [512, 64, 512, 64]);
    }

    #[test]
    fn enumeration_counts_match_divisor_products() {
        let g = FusedGemm { i: 16, k: 4, l: 8, j: 4 };
        let tilings = enumerate_tilings(&g, None);
        assert_eq!(
            tilings.len(),
            divisors(16).len() * divisors(4).len() * divisors(8).len() * divisors(4).len()
        );
    }

    #[test]
    fn every_tiling_factors_exactly() {
        let g = FusedGemm { i: 48, k: 6, l: 20, j: 9 };
        for t in enumerate_tilings(&g, None) {
            assert_eq!(t.xd[0] * t.xg[0], 48);
            assert_eq!(t.xd[1] * t.xg[1], 6);
            assert_eq!(t.xd[2] * t.xg[2], 20);
            assert_eq!(t.xd[3] * t.xg[3], 9);
        }
    }

    #[test]
    fn prefilter_only_drops_infeasible() {
        let g = FusedGemm { i: 64, k: 16, l: 64, j: 16 };
        let all = enumerate_tilings(&g, None);
        let cap = 4096.0;
        let kept = enumerate_tilings(&g, Some(cap));
        assert!(kept.len() < all.len());
        for t in &all {
            let keep = min_footprint(t) <= cap;
            assert_eq!(kept.contains(t), keep, "tiling {t:?}");
        }
    }

    #[test]
    fn prop_min_footprint_positive_and_monotone_in_granules() {
        prop::quick(
            64,
            0xF00D,
            |rng, size| {
                let s = size.max(2);
                Tiling {
                    xd: [1; 4],
                    xg: [
                        rng.range(1, s),
                        rng.range(1, s),
                        rng.range(1, s),
                        rng.range(1, s),
                    ],
                }
            },
            |t| {
                let f = min_footprint(t);
                if f <= 0.0 {
                    return Err("non-positive footprint".into());
                }
                let mut bigger = *t;
                bigger.xg[0] *= 2;
                if min_footprint(&bigger) <= f {
                    return Err("not monotone in i_g".into());
                }
                Ok(())
            },
        );
    }
}
