//! Preset accelerators and workloads from the paper's evaluation.

use super::accel::{Accelerator, EnergyModel};
use super::workload::Workload;

const MB: usize = 1 << 20;
const KB: usize = 1 << 10;
const GB: f64 = 1.0e9;

/// Accel. 1 (paper §VII-A): NVDLA-like — 4 PE arrays, 1 MB buffer,
/// 60 GB/s DRAM, 32×32 PEs, 1 GHz.
pub fn accel1() -> Accelerator {
    Accelerator {
        name: "accel1-nvdla".into(),
        num_arrays: 4,
        pe_rows: 32,
        pe_cols: 32,
        buffer_bytes: MB,
        dram_bw: 60.0 * GB,
        freq: 1.0e9,
        bytes_per_word: 2,
        energy: EnergyModel::default(),
    }
}

/// Accel. 2 (paper §VII-A): TPU-like — 4 PE arrays, 4 MB buffer,
/// 128 GB/s DRAM, 128×128 PEs, 1 GHz.
pub fn accel2() -> Accelerator {
    Accelerator {
        name: "accel2-tpu".into(),
        num_arrays: 4,
        pe_rows: 128,
        pe_cols: 128,
        buffer_bytes: 4 * MB,
        dram_bw: 128.0 * GB,
        freq: 1.0e9,
        bytes_per_word: 2,
        energy: EnergyModel::default(),
    }
}

/// Coral NPU (paper Table III / Fig. 26): 1×16×16, 32 KB, 1.6 GB/s.
pub fn coral() -> Accelerator {
    Accelerator {
        name: "coral".into(),
        num_arrays: 1,
        pe_rows: 16,
        pe_cols: 16,
        buffer_bytes: 32 * KB,
        dram_bw: 1.6 * GB,
        freq: 1.0e9,
        bytes_per_word: 2,
        energy: EnergyModel::default(),
    }
}

/// Zheng et al. design [89] (Table III): 1×32×32, 512 KB, 2 GB/s.
pub fn design89() -> Accelerator {
    Accelerator {
        name: "design89".into(),
        num_arrays: 1,
        pe_rows: 32,
        pe_cols: 32,
        buffer_bytes: 512 * KB,
        dram_bw: 2.0 * GB,
        freq: 1.0e9,
        bytes_per_word: 2,
        energy: EnergyModel::default(),
    }
}

/// SET [9]/Crane [28] tiled architecture (Table III): 16×32×32, 16 MB, 8 GB/s.
pub fn set_accel() -> Accelerator {
    Accelerator {
        name: "set".into(),
        num_arrays: 16,
        pe_rows: 32,
        pe_cols: 32,
        buffer_bytes: 16 * MB,
        dram_bw: 8.0 * GB,
        freq: 1.0e9,
        bytes_per_word: 2,
        energy: EnergyModel::default(),
    }
}

/// GPU proxy for the Table II substitution (DESIGN.md §7): A100-40GB
/// class — 108 SM-like arrays, 40 MB L2-as-buffer, 1.5 TB/s HBM2e,
/// 1.41 GHz; an 8×16 "array" approximates one SM's tensor-core MAC rate
/// (f16: 1024 MAC/cycle/SM ≈ 8×16×8; we keep a 2-D 32×32 logical shape
/// with 1024 MACs/cycle).
pub fn gpu_proxy() -> Accelerator {
    Accelerator {
        name: "gpu-a100-proxy".into(),
        num_arrays: 108,
        pe_rows: 32,
        pe_cols: 32,
        buffer_bytes: 40 * MB,
        dram_bw: 1555.0 * GB,
        freq: 1.41e9,
        bytes_per_word: 2,
        energy: EnergyModel::default(),
    }
}

/// Canonical accelerator preset names (for error hints and docs); the
/// lookup also accepts the aliases listed in [`accel_by_name`].
pub const ACCEL_NAMES: &[&str] = &["accel1", "accel2", "coral", "design89", "set", "gpu"];

/// Case-insensitive preset lookup. Prefer resolving through
/// [`crate::search::AccelSpec`], which wraps the miss in a structured
/// [`crate::error::MmeeError::UnknownAccel`].
pub fn accel_by_name(name: &str) -> Option<Accelerator> {
    match name.to_ascii_lowercase().as_str() {
        "accel1" | "accel1-nvdla" | "nvdla" => Some(accel1()),
        "accel2" | "accel2-tpu" | "tpu" => Some(accel2()),
        "coral" => Some(coral()),
        "design89" => Some(design89()),
        "set" => Some(set_accel()),
        "gpu" | "gpu-a100-proxy" => Some(gpu_proxy()),
        _ => None,
    }
}

// ----------------------------------------------------------------- models

/// BERT-Base attention: d_model 768, 12 heads, d_head 64.
pub fn bert_base(seq: usize) -> Workload {
    Workload::attention("bert-base", seq, 64, 12)
}

/// GPT-3-13B attention: d_model 5120, 40 heads, d_head 128.
pub fn gpt3_13b(seq: usize) -> Workload {
    Workload::attention("gpt3-13b", seq, 128, 40)
}

/// PaLM-62B attention: d_model 8192, 32 heads, d_head 256.
pub fn palm_62b(seq: usize) -> Workload {
    Workload::attention("palm-62b", seq, 256, 32)
}

/// GPT-3-6.7B attention: d_model 4096, 32 heads, d_head 128 (Fig. 16).
pub fn gpt3_6_7b_attention(seq: usize) -> Workload {
    Workload::attention("gpt3-6.7b", seq, 128, 32)
}

/// GPT-3-6.7B fused FFN pair (Fig. 15): tokens × d_model × 4·d_model ×
/// d_model, following Orojenesis's fused-FFN setup.
pub fn gpt3_6_7b_ffn(tokens: usize) -> Workload {
    Workload::gemm_pair("gpt3-6.7b-ffn", tokens, 4096, 16384, 4096)
}

/// Table IV workloads.
pub fn cc1() -> Workload {
    Workload::conv_chain("cc1", 112 * 112, 64, 192, 128, 3, 1)
}
pub fn cc2() -> Workload {
    Workload::conv_chain("cc2", 56 * 56, 64, 64, 64, 1, 1)
}
pub fn mlp_chimera() -> Workload {
    Workload::gemm_pair("mlp", 768, 64, 384, 64)
}
pub fn ffn_bert() -> Workload {
    Workload::gemm_pair("ffn", 2048, 768, 3072, 768)
}

/// The paper's main 3×3 evaluation grid (Figs. 17/18, Table I).
pub fn main_grid() -> Vec<Workload> {
    vec![
        bert_base(512),
        bert_base(4096),
        bert_base(16384),
        gpt3_13b(2048),
        gpt3_13b(4096),
        gpt3_13b(16384),
        palm_62b(2048),
        palm_62b(4096),
        palm_62b(16384),
    ]
}

/// Canonical workload preset names (for error hints and docs).
pub const WORKLOAD_NAMES: &[&str] = &[
    "bert-base",
    "gpt3-13b",
    "palm-62b",
    "gpt3-6.7b",
    "gpt3-6.7b-ffn",
    "cc1",
    "cc2",
    "mlp",
    "ffn",
];

/// Case-insensitive preset lookup. Prefer resolving through
/// [`crate::search::WorkloadSpec`], which wraps the miss in a structured
/// [`crate::error::MmeeError::UnknownWorkload`].
pub fn workload_by_name(name: &str, seq: usize) -> Option<Workload> {
    match name.to_ascii_lowercase().as_str() {
        "bert-base" | "bert" => Some(bert_base(seq)),
        "gpt3-13b" | "gpt" => Some(gpt3_13b(seq)),
        "palm-62b" | "palm" => Some(palm_62b(seq)),
        "gpt3-6.7b" => Some(gpt3_6_7b_attention(seq)),
        "gpt3-6.7b-ffn" => Some(gpt3_6_7b_ffn(seq)),
        "cc1" => Some(cc1()),
        "cc2" => Some(cc2()),
        "mlp" => Some(mlp_chimera()),
        "ffn" => Some(ffn_bert()),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_accel_parameters() {
        let a1 = accel1();
        assert_eq!((a1.num_arrays, a1.pe_rows, a1.buffer_bytes), (4, 32, MB));
        let a2 = accel2();
        assert_eq!((a2.num_arrays, a2.pe_rows, a2.buffer_bytes), (4, 128, 4 * MB));
        assert_eq!(set_accel().num_arrays, 16);
        assert_eq!(coral().buffer_bytes, 32 * KB);
    }

    #[test]
    fn lookup_by_name() {
        assert!(accel_by_name("accel1").is_some());
        assert!(accel_by_name("nope").is_none());
        assert_eq!(workload_by_name("palm", 2048).unwrap().gemm.k, 256);
        assert_eq!(workload_by_name("cc1", 0).unwrap().name, "cc1");
    }

    #[test]
    fn lookup_is_case_insensitive() {
        assert!(accel_by_name("Accel1").is_some());
        assert!(accel_by_name("CORAL").is_some());
        assert_eq!(workload_by_name("BERT-Base", 512).unwrap().gemm.k, 64);
        assert_eq!(workload_by_name("GPT", 2048).unwrap().gemm.k, 128);
    }

    #[test]
    fn canonical_names_all_resolve() {
        for n in ACCEL_NAMES {
            assert!(accel_by_name(n).is_some(), "{n}");
        }
        for n in WORKLOAD_NAMES {
            assert!(workload_by_name(n, 512).is_some(), "{n}");
        }
    }

    #[test]
    fn main_grid_is_three_by_three() {
        let grid = main_grid();
        assert_eq!(grid.len(), 9);
        assert!(grid.iter().all(|w| w.has_softmax()));
    }
}
