//! Workload specifications (paper §VII: attention of BERT-Base /
//! GPT-3-13B / PaLM-62B, GPT-3-6.7B FFN pairs, conv chains via im2col,
//! two-GEMM MLP/FFN shapes).
//!
//! Every workload normalizes to a [`FusedGemm`]: producer
//! `A(I×K)·B(K×L) → C(I×L)`, consumer `C(I×L)·D(L×J) → E(I×J)`, with an
//! optional softmax on C rows (attention) and a batch/head multiplier.

/// A fused producer/consumer GEMM pair in the paper's `[I, K, L, J]`
/// dimension convention.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct FusedGemm {
    pub i: usize,
    pub k: usize,
    pub l: usize,
    pub j: usize,
}

impl FusedGemm {
    pub fn dims(&self) -> [usize; 4] {
        [self.i, self.k, self.l, self.j]
    }
    /// MACs of Op1 / Op2 (single head/batch instance).
    pub fn macs_op1(&self) -> f64 {
        self.i as f64 * self.k as f64 * self.l as f64
    }
    pub fn macs_op2(&self) -> f64 {
        self.i as f64 * self.l as f64 * self.j as f64
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WorkloadKind {
    /// `softmax(Q·Kᵀ)·V`: I = L = seq_len, K = J = d_head.
    Attention,
    /// Plain fused GEMM chain (FFN, MLP, im2col'd conv chain).
    GemmPair,
}

/// A named workload instance.
#[derive(Debug, Clone, PartialEq)]
pub struct Workload {
    pub name: String,
    pub kind: WorkloadKind,
    pub gemm: FusedGemm,
    /// Independent instances (attention heads × batch); instances map to
    /// PE arrays (paper §V: "computations across different heads are
    /// independent ... mapped onto separate PE arrays").
    pub instances: usize,
    /// Softmax cost factor `c_softmax` (paper §V-D; 10 in §VII-A).
    pub c_softmax: f64,
}

impl Workload {
    /// Encoder/prefill attention for one transformer layer, all heads.
    pub fn attention(name: &str, seq: usize, d_head: usize, heads: usize) -> Workload {
        Workload {
            name: format!("{name}-{}", fmt_seq(seq)),
            kind: WorkloadKind::Attention,
            gemm: FusedGemm { i: seq, k: d_head, l: seq, j: d_head },
            instances: heads,
            c_softmax: 10.0,
        }
    }

    /// A fused GEMM pair (no softmax).
    pub fn gemm_pair(name: &str, i: usize, k: usize, l: usize, j: usize) -> Workload {
        Workload {
            name: name.to_string(),
            kind: WorkloadKind::GemmPair,
            gemm: FusedGemm { i, k, l, j },
            instances: 1,
            c_softmax: 0.0,
        }
    }

    /// A convolution chain converted to a GEMM pair via im2col
    /// (paper Table IV): shapes `[H×W, Cin, Cmid, Cout, k1², k2²]`.
    /// Conv1: I = H·W output pixels, K = Cin·k1², L = Cmid.
    /// Conv2 consumes conv1's output: reduction = Cmid·k2², J = Cout.
    /// For k2 = 1 (pointwise) the intermediate is exactly C; for k2 > 1
    /// the im2col re-reads neighbouring rows, which we conservatively
    /// model with the same fused-GEMM shape (documented substitution).
    pub fn conv_chain(
        name: &str,
        hw: usize,
        cin: usize,
        cmid: usize,
        cout: usize,
        k1: usize,
        k2: usize,
    ) -> Workload {
        Workload {
            name: name.to_string(),
            kind: WorkloadKind::GemmPair,
            gemm: FusedGemm {
                i: hw,
                k: cin * k1 * k1,
                l: cmid * k2 * k2,
                j: cout,
            },
            instances: 1,
            c_softmax: 0.0,
        }
    }

    pub fn has_softmax(&self) -> bool {
        matches!(self.kind, WorkloadKind::Attention)
    }

    /// Total MACs across instances, no recomputation.
    pub fn total_macs(&self) -> f64 {
        (self.gemm.macs_op1() + self.gemm.macs_op2()) * self.instances as f64
    }

    /// Energy multiplier: all instances execute.
    pub fn energy_multiplier(&self) -> f64 {
        self.instances as f64
    }

    /// Latency multiplier given `num_arrays` PE arrays running instances
    /// in parallel: ceil(instances / arrays) waves.
    pub fn latency_multiplier(&self, num_arrays: usize) -> f64 {
        (self.instances + num_arrays - 1).div_euclid(num_arrays).max(1) as f64
    }
}

fn fmt_seq(seq: usize) -> String {
    if seq % 1024 == 0 {
        format!("{}k", seq / 1024)
    } else {
        format!("{seq}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn attention_dims() {
        let w = Workload::attention("bert-base", 512, 64, 12);
        assert_eq!(w.gemm, FusedGemm { i: 512, k: 64, l: 512, j: 64 });
        assert_eq!(w.instances, 12);
        assert!(w.has_softmax());
        assert_eq!(w.name, "bert-base-512");
        let w4k = Workload::attention("bert-base", 4096, 64, 12);
        assert_eq!(w4k.name, "bert-base-4k");
    }

    #[test]
    fn mac_counts() {
        let w = Workload::attention("t", 512, 64, 12);
        // per head: 512*512*64 per op; both ops; ×12 heads
        let expect = 2.0 * 512.0 * 512.0 * 64.0 * 12.0;
        assert_eq!(w.total_macs(), expect);
    }

    #[test]
    fn latency_multiplier_waves() {
        let w = Workload::attention("t", 512, 64, 12);
        assert_eq!(w.latency_multiplier(4), 3.0);
        assert_eq!(w.latency_multiplier(16), 1.0);
        assert_eq!(w.latency_multiplier(5), 3.0);
    }

    #[test]
    fn conv_chain_im2col() {
        // CC1 [112², 64, 192, 128, 3², 1²] (paper Table IV)
        let w = Workload::conv_chain("cc1", 112 * 112, 64, 192, 128, 3, 1);
        assert_eq!(w.gemm.i, 12544);
        assert_eq!(w.gemm.k, 64 * 9);
        assert_eq!(w.gemm.l, 192);
        assert_eq!(w.gemm.j, 128);
        assert!(!w.has_softmax());
    }
}
