//! Spatial accelerator descriptions (paper §II-B, Fig. 2(b)).
//!
//! An accelerator is a set of PE arrays behind one shared on-chip buffer,
//! with a DRAM channel and an SFU for softmax. The energy model follows
//! Interstellar-style 28nm constants (paper §VII-A, [81]) and is fully
//! user-overridable.

use crate::util::json::Json;

/// Per-word / per-MAC energy constants in joules. "word" = one element
/// (bf16/fp16, 2 bytes) unless `bytes_per_word` says otherwise.
#[derive(Debug, Clone, PartialEq)]
pub struct EnergyModel {
    /// DRAM <-> on-chip buffer, J/word (≈100 pJ/B class for LPDDR @28nm).
    pub e_dram: f64,
    /// On-chip buffer <-> register file, J/word (MB-scale SRAM).
    pub e_buf: f64,
    /// One MAC, J (16-bit @ 28nm).
    pub e_mac: f64,
    /// Softmax per element normalised work unit, J. The paper's
    /// `c_softmax` multiplier is folded into the query encoding, so this
    /// is the per-unit SFU energy.
    pub e_sfu: f64,
    /// Buffer-occupancy (leakage proxy) J/word of peak occupancy; gives
    /// the "DRAM-buffer energy proportional to buffer size" term the
    /// paper's optimality proof (§VI-C) relies on.
    pub e_bs: f64,
}

impl Default for EnergyModel {
    fn default() -> Self {
        // 28nm-class constants per 2-byte word (Interstellar [81] style):
        // DRAM ~100 pJ/B -> 200 pJ/word; large SRAM ~3 pJ/B -> 6 pJ/word;
        // 16-bit MAC ~0.56 pJ; SFU exp/div unit ~0.56 pJ/op unit.
        EnergyModel {
            e_dram: 200.0e-12,
            e_buf: 6.0e-12,
            e_mac: 0.56e-12,
            e_sfu: 0.56e-12,
            e_bs: 0.01e-12,
        }
    }
}

/// One accelerator configuration (paper §VII-A).
#[derive(Debug, Clone, PartialEq)]
pub struct Accelerator {
    pub name: String,
    /// Number of identical PE arrays (heads are mapped across arrays).
    pub num_arrays: usize,
    /// Logical PE array shape (rows x cols). Square for the main
    /// experiments; Fig. 27 explores reshaping.
    pub pe_rows: usize,
    pub pe_cols: usize,
    /// On-chip buffer capacity in bytes (shared, double-buffered).
    pub buffer_bytes: usize,
    /// DRAM bandwidth, bytes/second.
    pub dram_bw: f64,
    /// Clock, Hz.
    pub freq: f64,
    /// Element size in bytes (bf16 = 2).
    pub bytes_per_word: usize,
    pub energy: EnergyModel,
}

impl Accelerator {
    pub fn capacity_words(&self) -> usize {
        self.buffer_bytes / self.bytes_per_word
    }

    /// MACs per cycle across one PE array.
    pub fn macs_per_cycle(&self) -> usize {
        self.pe_rows * self.pe_cols
    }

    /// Seconds to move one word over DRAM.
    pub fn sec_per_word(&self) -> f64 {
        self.bytes_per_word as f64 / self.dram_bw
    }

    pub fn sec_per_cycle(&self) -> f64 {
        1.0 / self.freq
    }

    /// The 8-entry hardware parameter vector consumed by the AOT
    /// evaluation graph (layout.HW_PARAMS order) and the native evaluator.
    pub fn hw_vector(&self) -> HwVector {
        HwVector {
            e_dram: self.energy.e_dram,
            e_buf: self.energy.e_buf,
            e_mac: self.energy.e_mac,
            e_sfu: self.energy.e_sfu,
            e_bs: self.energy.e_bs,
            sec_per_word: self.sec_per_word(),
            sec_per_cycle: self.sec_per_cycle(),
            capacity_words: self.capacity_words() as f64,
        }
    }

    /// Same accelerator with a different buffer size (Figs. 15/16 sweeps).
    pub fn with_buffer_bytes(&self, bytes: usize) -> Accelerator {
        Accelerator { buffer_bytes: bytes, ..self.clone() }
    }

    /// Same accelerator with a reshaped logical PE array (Fig. 27).
    pub fn with_pe_shape(&self, rows: usize, cols: usize) -> Accelerator {
        Accelerator { pe_rows: rows, pe_cols: cols, ..self.clone() }
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::str(self.name.clone())),
            ("num_arrays", Json::num(self.num_arrays as f64)),
            ("pe_rows", Json::num(self.pe_rows as f64)),
            ("pe_cols", Json::num(self.pe_cols as f64)),
            ("buffer_bytes", Json::num(self.buffer_bytes as f64)),
            ("dram_bw", Json::num(self.dram_bw)),
            ("freq", Json::num(self.freq)),
            ("bytes_per_word", Json::num(self.bytes_per_word as f64)),
        ])
    }

    /// Every parameter must be strictly positive: zeros (or negative
    /// JSON numbers, which `as usize` floors to zero) would divide by
    /// zero in `capacity_words`/`features` deep inside the request path,
    /// which is contracted never to panic.
    pub fn from_json(j: &Json) -> crate::error::Result<Accelerator> {
        let get = |k: &str| -> crate::error::Result<f64> {
            match j.get(k).and_then(Json::as_f64) {
                Some(v) if v > 0.0 && v.is_finite() => Ok(v),
                Some(_) => Err(crate::error::MmeeError::Parse(format!(
                    "accelerator '{k}' must be a positive finite number"
                ))),
                None => Err(crate::error::MmeeError::Parse(format!(
                    "accelerator config missing '{k}'"
                ))),
            }
        };
        // Integer fields reject fractional values outright — silently
        // flooring 8.9 PE rows to 8 would compute a mapping for
        // different hardware than the client asked for.
        let get_int = |k: &str| -> crate::error::Result<usize> {
            let v = get(k)?;
            if v.fract() != 0.0 || v < 1.0 {
                return Err(crate::error::MmeeError::Parse(format!(
                    "accelerator '{k}' must be a positive integer"
                )));
            }
            Ok(v as usize)
        };
        Ok(Accelerator {
            name: j
                .get("name")
                .and_then(Json::as_str)
                .unwrap_or("custom")
                .to_string(),
            num_arrays: get_int("num_arrays")?,
            pe_rows: get_int("pe_rows")?,
            pe_cols: get_int("pe_cols")?,
            buffer_bytes: get_int("buffer_bytes")?,
            dram_bw: get("dram_bw")?,
            freq: get("freq")?,
            bytes_per_word: get_int("bytes_per_word")?,
            energy: EnergyModel::default(),
        })
    }
}

/// Flat hardware parameter vector — the runtime input of the AOT graph.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct HwVector {
    pub e_dram: f64,
    pub e_buf: f64,
    pub e_mac: f64,
    pub e_sfu: f64,
    pub e_bs: f64,
    pub sec_per_word: f64,
    pub sec_per_cycle: f64,
    pub capacity_words: f64,
}

impl HwVector {
    pub fn to_f32_array(&self) -> [f32; 8] {
        [
            self.e_dram as f32,
            self.e_buf as f32,
            self.e_mac as f32,
            self.e_sfu as f32,
            self.e_bs as f32,
            self.sec_per_word as f32,
            self.sec_per_cycle as f32,
            self.capacity_words as f32,
        ]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;

    #[test]
    fn derived_quantities() {
        let a = presets::accel1();
        assert_eq!(a.capacity_words(), 1 << 20 >> 1); // 1 MB / 2B
        assert_eq!(a.macs_per_cycle(), 32 * 32);
        assert!((a.sec_per_cycle() - 1e-9).abs() < 1e-18);
    }

    #[test]
    fn hw_vector_matches_layout_order() {
        let a = presets::accel2();
        let v = a.hw_vector().to_f32_array();
        assert_eq!(v[7], a.capacity_words() as f32);
        assert!((v[5] - a.sec_per_word() as f32).abs() < 1e-18);
    }

    #[test]
    fn json_roundtrip() {
        let a = presets::accel1();
        let b = Accelerator::from_json(&a.to_json()).unwrap();
        assert_eq!(a.name, b.name);
        assert_eq!(a.buffer_bytes, b.buffer_bytes);
        assert_eq!(a.pe_rows, b.pe_rows);
    }

    #[test]
    fn buffer_and_shape_overrides() {
        let a = presets::accel1();
        assert_eq!(a.with_buffer_bytes(65536).buffer_bytes, 65536);
        let r = a.with_pe_shape(8, 128);
        assert_eq!((r.pe_rows, r.pe_cols), (8, 128));
        assert_eq!(r.buffer_bytes, a.buffer_bytes);
    }
}
