//! Accelerator, energy-model and workload configuration.

pub mod accel;
pub mod workload;
pub mod presets;

pub use accel::{Accelerator, EnergyModel, HwVector};
pub use workload::{FusedGemm, Workload, WorkloadKind};
