//! `mmee` — the MMEE dataflow-mapper CLI (L3 leader entrypoint).
//!
//! ```text
//! mmee optimize --workload bert-base --seq 4096 --accel accel2 \
//!               --objective energy [--backend native|xla|branchy]
//! mmee pareto   --workload palm-62b --seq 4096 --accel accel2
//! mmee sweep    --workload bert-base --accel accel1 --objective latency \
//!               --dim seq --from 128 --to 4096 --step x2
//!                                   # dynamic-shape warm-started sweep
//! mmee sweep --smoke                # warm-vs-cold equality self-check
//! mmee validate [--charts]          # model vs simulator
//! mmee serve [--tcp host:port] [--workers N] [--route-above M]
//!                                   # JSON-lines mapping service
//!                                   # (MMEE_NET=threads|epoll picks the
//!                                   #  TCP front end; see README)
//! mmee serve --batch reqs.json      # one JSON-array file, batched
//! mmee serve --smoke                # deadline/degradation self-check
//! mmee cluster [--workers N] [--worker-threads T] [--tcp host:port]
//!                                   # multi-process sharded front-end
//! mmee cluster --smoke              # spawn/kill/restart self-check
//! mmee bench-fig <13..27|all>       # regenerate paper figures
//! mmee bench-table <1..4|all>       # regenerate paper tables
//! mmee bench-all [--out results]    # everything + summary.md
//! ```
//!
//! All subcommands speak the typed request pipeline: preset names are
//! resolved through `WorkloadSpec`/`AccelSpec` (case-insensitive, with
//! the valid values listed on a miss) and failures are structured
//! `MmeeError`s, not panics.

use mmee::baselines::tileflow::TileFlow;
use mmee::baselines::Mapper;
use mmee::coordinator::service;
use mmee::error::{MmeeError, Result};
use mmee::report::{figures, tables, Report};
use mmee::search::{
    AccelSpec, BatchRequest, MappingRequest, MmeeEngine, Objective, WorkloadSpec,
};
use mmee::util::cli::Args;

fn engine_for(args: &Args) -> Result<MmeeEngine> {
    let backend = args.flag_or("backend", "native");
    let mut builder = MmeeEngine::builder();
    builder = if backend.eq_ignore_ascii_case("xla") {
        // PJRT handles must not cross threads: probe availability once
        // (fail fast on missing artifacts), then let each serving
        // worker build its own instance.
        mmee::eval::backend_by_name("xla")?;
        builder.backend_factory("xla", || mmee::eval::backend_by_name("xla"))
    } else {
        builder.backend(mmee::eval::shared_backend_by_name(backend)?)
    };
    if let Some(t) = args.flag("route-above") {
        let threshold = t.parse().map_err(|_| {
            MmeeError::Parse(format!("--route-above expects a mapping count, got '{t}'"))
        })?;
        builder = builder.route_above(threshold);
    }
    Ok(builder.build())
}

fn main() -> Result<()> {
    let args = Args::from_env();
    match args.subcommand.as_deref() {
        Some("optimize") => cmd_optimize(&args),
        Some("pareto") => cmd_pareto(&args),
        Some("sweep") => cmd_sweep(&args),
        Some("validate") => cmd_validate(&args),
        Some("serve") => cmd_serve(&args),
        Some("cluster") => cmd_cluster(&args),
        Some("bench-fig") => cmd_bench_fig(&args),
        Some("bench-table") => cmd_bench_table(&args),
        Some("bench-all") => cmd_bench_all(&args),
        Some("version") => {
            print_version();
            Ok(())
        }
        _ => {
            if args.has("version") {
                print_version();
            } else {
                eprintln!("{HELP}");
            }
            Ok(())
        }
    }
}

/// `mmee version` / `mmee --version`: the build version plus the lane
/// ISA the fused eval kernel dispatched to on this host (reflects an
/// `MMEE_ISA` override — see README § Performance).
fn print_version() {
    println!(
        "mmee {} (eval isa: {})",
        env!("CARGO_PKG_VERSION"),
        mmee::eval::simd::active_name()
    );
}

const HELP: &str = "mmee — Matrix Multiplication Encoded Enumeration dataflow mapper
subcommands: optimize | pareto | sweep | validate | serve | cluster | bench-fig | bench-table | bench-all | version
see rust/src/main.rs header for flags";

fn request_from(args: &Args) -> Result<MappingRequest> {
    let workload = WorkloadSpec::preset(
        args.flag_or("workload", "bert-base"),
        args.usize_flag("seq", 512),
    );
    let accel = AccelSpec::preset(args.flag_or("accel", "accel1"));
    let objective = Objective::parse(args.flag_or("objective", "energy"))?;
    Ok(MappingRequest::new(workload, accel, objective))
}

fn cmd_optimize(args: &Args) -> Result<()> {
    let req = request_from(args)?;
    let engine = engine_for(args)?;
    let (w, accel) = req.resolve()?;
    if args.has("tileflow") {
        let s = TileFlow::default().optimize(&w, &accel, req.objective)?;
        println!("{:#}", s.to_json());
        if args.has("loopnest") {
            println!("\n{}", s.render_loopnest(&w, &accel));
        }
        return Ok(());
    }
    let plan = engine.plan(&req)?;
    println!("{:#}", plan.to_json());
    if args.has("loopnest") {
        println!("\n{}", plan.solution.render_loopnest(&w, &accel));
    }
    Ok(())
}

fn cmd_pareto(args: &Args) -> Result<()> {
    let req = request_from(args)?;
    let (w, accel) = req.resolve()?;
    let engine = engine_for(args)?;
    let (front, stats) = engine.pareto_energy_latency(&w, &accel)?;
    println!(
        "# {} on {}: {} Pareto points / {} mappings in {:?}",
        w.name,
        accel.name,
        front.len(),
        stats.mappings,
        stats.elapsed
    );
    println!("energy_j,latency_s,recompute");
    for p in front.points() {
        println!(
            "{},{},{}",
            p.x,
            p.y,
            MmeeEngine::candidates()[p.candidate].recompute()
        );
    }
    Ok(())
}

/// Parse `--dim`: `seq` (the attention convention, I and L) or a
/// string of i/k/l/j letters naming the swept GEMM dims.
fn parse_sweep_dims(s: &str) -> Result<Vec<usize>> {
    if s.eq_ignore_ascii_case("seq") {
        return Ok(vec![0, 2]);
    }
    s.chars()
        .map(|c| match c.to_ascii_lowercase() {
            'i' => Ok(0),
            'k' => Ok(1),
            'l' => Ok(2),
            'j' => Ok(3),
            other => Err(MmeeError::Parse(format!(
                "--dim expects 'seq' or a string of i/k/l/j letters, got '{other}'"
            ))),
        })
        .collect()
}

/// Expand `--from/--to/--step` into the swept values: `xN` multiplies
/// (geometric sweeps, e.g. prefill doublings), `+N` or a bare `N` adds
/// (decode traces step by 1).
fn sweep_values(from: usize, to: usize, step: &str) -> Result<Vec<usize>> {
    let bad = || MmeeError::Parse(format!("--step expects 'xN' or '+N', got '{step}'"));
    let (mul, add) = if let Some(f) = step.strip_prefix('x') {
        (f.parse::<usize>().map_err(|_| bad())?, 0)
    } else {
        let s = step.strip_prefix('+').unwrap_or(step);
        (1, s.parse::<usize>().map_err(|_| bad())?)
    };
    if mul == 0 || (mul == 1 && add == 0) || from == 0 {
        return Err(MmeeError::Parse(format!(
            "non-advancing sweep: from {from} step {step}"
        )));
    }
    let mut out = Vec::new();
    let mut v = from;
    while v <= to {
        out.push(v);
        v = v * mul + add;
    }
    if out.is_empty() {
        return Err(MmeeError::Parse(format!("empty sweep: from {from} to {to}")));
    }
    Ok(out)
}

/// `mmee sweep`: plan a dynamic-shape sweep with warm-started search
/// (delta surface builds + incumbent-seeded passes). `--smoke` runs a
/// small built-in sweep and verifies every plan against a cold engine.
fn cmd_sweep(args: &Args) -> Result<()> {
    use mmee::search::SweepSpec;
    if args.has("smoke") {
        return sweep_smoke();
    }
    let base = request_from(args)?;
    let dims = parse_sweep_dims(args.flag_or("dim", "seq"))?;
    let from = args.usize_flag("from", 128);
    let to = args.usize_flag("to", 4096);
    let values = sweep_values(from, to, args.flag_or("step", "x2"))?;
    let engine = engine_for(args)?;
    let report = engine.plan_sweep(&base, &SweepSpec { dims, values })?;
    for (v, plan) in &report.plans {
        match plan {
            Ok(p) => println!(
                "{v}: {} / {} energy {:.3e} J latency {:.3e} s{}",
                p.solution.candidate.name(),
                p.solution.tiling.name(),
                p.solution.metrics.energy,
                p.solution.metrics.latency,
                if p.provenance.cache_hit { " (cached)" } else { "" }
            ),
            Err(e) => println!("{v}: error: {e}"),
        }
    }
    let s = &report.stats;
    eprintln!(
        "swept {} shapes in {:?}: {} plan hits, {} family hits, {} delta + {} cold builds \
         ({:?} building), {} seeded passes",
        s.shapes,
        s.elapsed,
        s.plan_hits,
        s.family_hits,
        s.delta_builds,
        s.cold_builds,
        s.boundary_build,
        s.seeded_passes
    );
    Ok(())
}

/// CI self-check: a small sweep must return exactly what a cold engine
/// returns per shape, and the build mix must show the warm-start chain.
fn sweep_smoke() -> Result<()> {
    use mmee::search::SweepSpec;
    let base = MappingRequest::preset("bert-base", 64, "accel1", Objective::Energy);
    let engine = MmeeEngine::native();
    let report = engine.plan_sweep(&base, &SweepSpec::seq(vec![48, 64, 96]))?;
    let cold = MmeeEngine::native();
    let accel = AccelSpec::preset("accel1").resolve()?;
    for (v, plan) in &report.plans {
        let p = plan.as_ref().map_err(|e| e.clone())?;
        let mut w = WorkloadSpec::preset("bert-base", 64).resolve()?;
        w.gemm.i = *v;
        w.gemm.l = *v;
        let s = cold.optimize(&w, &accel, Objective::Energy)?;
        if p.solution.candidate != s.candidate
            || p.solution.tiling != s.tiling
            || p.solution.metrics.energy != s.metrics.energy
        {
            return Err(MmeeError::Internal(format!(
                "sweep smoke: warm plan diverges from cold optimize at seq {v}"
            )));
        }
    }
    if report.stats.cold_builds != 1 || report.stats.delta_builds != 2 {
        return Err(MmeeError::Internal(format!(
            "sweep smoke: unexpected build mix {:?}",
            report.stats
        )));
    }
    println!("sweep smoke ok: 3 shapes, warm == cold, 1 cold + 2 delta builds");
    Ok(())
}

fn cmd_validate(args: &Args) -> Result<()> {
    let mut r = Report::new(args.flag_or("out", "results"))?;
    figures::fig13(&mut r)?;
    figures::fig14(&mut r)?;
    if args.has("charts") {
        use mmee::loopnest::{BufferingLevels, Candidate, LoopOrder, Stationary};
        use mmee::sim::charts;
        let w = WorkloadSpec::preset("bert-base", 512).resolve()?;
        let accel = AccelSpec::preset("accel1").resolve()?;
        let cand = Candidate {
            order: LoopOrder::flash(),
            levels: BufferingLevels { a: 4, b: 4, d: 4, e: 1 },
            sm1: Stationary::Weight,
            sm2: Stationary::Weight,
        };
        let t = mmee::tiling::Tiling { xd: [8, 1, 8, 1], xg: [64, 64, 64, 64] };
        let ch = charts::charts(&cand, &t, &accel, &w);
        println!("{}", charts::ascii_chart(&ch.occupancy, 8, "buffer utilisation (Fig. 5a)"));
        println!("{}", charts::ascii_chart(&ch.dram_per_stage, 8, "DRAM access curve (Fig. 5b)"));
    }
    r.finish("validate.md")?;
    Ok(())
}

/// CI self-check for the deadline contract: an expired budget is shed
/// with `deadline_exceeded`, a deterministically cancelled pass
/// degrades to an achieved in-surface incumbent, and the same request
/// without a deadline still returns the exact optimum. Finishes with a
/// TCP round-trip through whichever front end `MMEE_NET` selects
/// (threads or epoll), so CI exercises both wire paths.
fn serve_smoke() -> Result<()> {
    use mmee::coordinator::CancelToken;
    let engine = MmeeEngine::native();
    // (1) Queued-expiry shedding: a zero budget never reaches the
    // surface and never builds a boundary.
    let expired = MappingRequest::preset("bert-base", 128, "accel1", Objective::Energy)
        .with_deadline_ms(0);
    match engine.plan(&expired) {
        Err(e) if e.kind() == "deadline_exceeded" => {}
        other => {
            return Err(MmeeError::Internal(format!(
                "serve smoke: zero budget must shed with deadline_exceeded, got {other:?}"
            )))
        }
    }
    if engine.boundary_build_count() != 0 {
        return Err(MmeeError::Internal(
            "serve smoke: shed request paid for a boundary build".into(),
        ));
    }
    // (2) Deterministic mid-pass cancellation (the same token the
    // wall-clock deadline arms, tripped after exactly 2 tile-blocks)
    // degrades to a feasible achieved incumbent.
    let req = MappingRequest::preset("bert-base", 128, "accel1", Objective::Energy);
    let token = CancelToken::after_checks(2);
    let p = engine.plan_cancellable(&req, Some(&token))?;
    if !p.degraded || !p.solution.metrics.feasible || p.stats.blocks_cancelled == 0 {
        return Err(MmeeError::Internal(
            "serve smoke: cancelled pass must degrade to a feasible incumbent".into(),
        ));
    }
    // (3) The deadline-free request still gets the exact optimum, and
    // the anytime incumbent never beats it.
    let full = engine.plan(&req)?;
    if full.degraded || p.solution.metrics.energy < full.solution.metrics.energy {
        return Err(MmeeError::Internal(
            "serve smoke: degraded incumbent beat the full optimum".into(),
        ));
    }
    // (4) TCP round-trip through the MMEE_NET-selected front end: one
    // plan and one `{"op": "metrics"}` probe over a real socket, so the
    // smoke covers the wire path CI runs under both MMEE_NET values.
    let net = mmee::coordinator::NetMode::from_env().resolved();
    let tcp_engine = MmeeEngine::native();
    let (tx, rx) = std::sync::mpsc::channel();
    let server = std::thread::spawn(move || {
        service::serve_tcp(&tcp_engine, "127.0.0.1:0", Some(1), 2, |a| {
            let _ = tx.send(a);
        })
    });
    let addr = rx
        .recv()
        .map_err(|_| MmeeError::Internal("serve smoke: server never bound".into()))?;
    let served = (|| -> std::io::Result<()> {
        use std::io::{BufRead, BufReader, Write};
        let mut conn = std::net::TcpStream::connect(addr)?;
        conn.set_read_timeout(Some(std::time::Duration::from_secs(120)))?;
        let mut reader = BufReader::new(conn.try_clone()?);
        let mut ask = |line: &str| -> std::io::Result<String> {
            writeln!(conn, "{line}")?;
            let mut resp = String::new();
            reader.read_line(&mut resp)?;
            Ok(resp)
        };
        let plan = ask(r#"{"workload": "bert-base", "seq": 128, "accel": "accel1"}"#)?;
        let metrics = ask(r#"{"op": "metrics"}"#)?;
        let bad = |msg: &str, got: &str| {
            std::io::Error::other(format!("serve smoke: {msg}, got {got}"))
        };
        if !plan.contains("energy_j") {
            return Err(bad("TCP plan must answer with energy_j", &plan));
        }
        if !metrics.contains(&format!(r#""net":"{}""#, net.name())) {
            return Err(bad("metrics op must name the front end", &metrics));
        }
        if !metrics.contains(r#""p99_ns""#) {
            return Err(bad("metrics op must carry latency percentiles", &metrics));
        }
        Ok(())
    })();
    // Propagate a client-side failure before joining: if the client
    // never connected, the server is still blocked in accept and the
    // error exit (not the join) is what ends the process.
    served.map_err(|e| MmeeError::Internal(e.to_string()))?;
    let n = server
        .join()
        .map_err(|_| MmeeError::Internal("serve smoke: server panicked".into()))??;
    if n != 2 {
        return Err(MmeeError::Internal(format!(
            "serve smoke: TCP front end served {n} requests, expected 2"
        )));
    }
    println!(
        "serve smoke ok: shed on expiry, degraded to achieved incumbent, full pass exact, \
         {} front end round-trip",
        net.name()
    );
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    if args.has("smoke") {
        return serve_smoke();
    }
    let engine = engine_for(args)?;
    let workers = args.usize_flag("workers", mmee::coordinator::pool::default_workers());
    let n = if let Some(path) = args.flag("batch") {
        // Batch mode: one JSON-array file through the batch scheduler;
        // the response is a JSON array, one element per request.
        let text = std::fs::read_to_string(path)?;
        let batch = BatchRequest::parse(text.trim())?;
        let n = batch.len();
        let resp = service::handle(&engine, &service::Request::Batch(batch));
        println!("{:#}", resp.to_json());
        n
    } else if let Some(addr) = args.flag("tcp") {
        let announce = args.has("announce");
        service::serve_tcp(&engine, addr, None, workers, move |local| {
            if announce {
                // Cluster workers hand their ephemeral port back to the
                // parent through stdout; it is block-buffered when
                // piped, so flush or the parent hangs on the handshake.
                use std::io::Write;
                let mut out = std::io::stdout().lock();
                let _ = writeln!(out, "{}", mmee::cluster::proto::ready_line(local));
                let _ = out.flush();
            }
        })?
    } else {
        eprintln!(
            "mmee serve: JSON requests on stdin, one per line (backend: {}, {workers} workers)",
            engine.backend_name()
        );
        let stdin = std::io::stdin();
        service::serve_lines_concurrent(&engine, stdin.lock(), std::io::stdout(), workers)?
    };
    let (ph, pm) = engine.plan_cache_stats();
    let (bh, bm) = engine.boundary_cache_stats();
    eprintln!("served {n} requests (plan cache {ph}/{} hits, boundary cache {bh}/{})",
        ph + pm, bh + bm);
    Ok(())
}

/// `mmee cluster`: a front-end that shards requests across N spawned
/// `mmee serve --tcp` worker processes by (workload, accel) key, so
/// each worker owns a disjoint slice of the plan/boundary-cache
/// keyspace. Reads line-JSON from stdin (or serves `--tcp`), restarts
/// crashed workers, and answers `{"op": "stats"}` with per-worker
/// cache/restart counters.
fn cmd_cluster(args: &Args) -> Result<()> {
    if args.has("smoke") {
        return mmee::cluster::smoke(
            args.usize_flag("workers", 2),
            args.usize_flag("worker-threads", 2),
        );
    }
    let mut cfg = mmee::cluster::ClusterConfig::new(std::env::current_exe()?);
    cfg.workers = args.usize_flag("workers", 2);
    cfg.worker_threads = args.usize_flag("worker-threads", 2);
    cfg.backend = args.flag_or("backend", "native").to_string();
    let cluster = mmee::cluster::Cluster::start(cfg)?;
    let served = if let Some(addr) = args.flag("tcp") {
        cluster.serve_tcp(addr, None, |_| {})?
    } else {
        eprintln!(
            "mmee cluster: JSON requests on stdin, one per line ({} workers)",
            cluster.pool().num_workers()
        );
        let stdin = std::io::stdin();
        cluster.route(stdin.lock(), std::io::stdout())?
    };
    eprintln!("cluster served {served} requests ({} restarts)", cluster.total_restarts());
    cluster.shutdown();
    Ok(())
}

fn run_fig(n: &str, r: &mut Report, max_seq: usize) -> Result<()> {
    let accel = |name: &str| AccelSpec::preset(name).resolve();
    match n {
        "13" => figures::fig13(r),
        "14" => figures::fig14(r),
        "15" => figures::fig15(r),
        "16" => figures::fig16(r),
        "17" => figures::fig17_18(r, &accel("accel1")?, "fig17"),
        "18" => figures::fig17_18(r, &accel("accel2")?, "fig18"),
        "19" => figures::fig19(r),
        "20" => figures::fig20(r),
        "21" => figures::fig21(r),
        "22" => figures::fig22(r, max_seq),
        "23" => figures::fig23(r, max_seq.max(8192)),
        "24" => figures::fig24(r),
        "25" => figures::fig25(r),
        "26" => figures::fig26(r),
        "27" => figures::fig27(r),
        other => Err(MmeeError::Parse(format!("unknown figure '{other}' (valid: 13..27)"))),
    }
}

const ALL_FIGS: [&str; 15] = [
    "13", "14", "15", "16", "17", "18", "19", "20", "21", "22", "23", "24", "25", "26", "27",
];
const ALL_TABLES: [&str; 5] = ["1", "2", "3", "4", "pruning"];

fn cmd_bench_fig(args: &Args) -> Result<()> {
    let mut r = Report::new(args.flag_or("out", "results"))?;
    let max_seq = args.usize_flag("max-seq", 131072);
    let which = args.positional.first().map(String::as_str).unwrap_or("all");
    if which == "all" {
        for n in ALL_FIGS {
            run_fig(n, &mut r, max_seq)?;
        }
    } else {
        run_fig(which, &mut r, max_seq)?;
    }
    r.finish(&format!("fig{which}.md"))?;
    Ok(())
}

fn run_table(n: &str, r: &mut Report) -> Result<()> {
    match n {
        "1" => tables::table1(r),
        "2" => tables::table2(r),
        "3" => tables::table3(r),
        "4" => tables::table4(r),
        "pruning" => tables::pruning_check(r),
        other => Err(MmeeError::Parse(format!(
            "unknown table '{other}' (valid: 1, 2, 3, 4, pruning)"
        ))),
    }
}

fn cmd_bench_table(args: &Args) -> Result<()> {
    let mut r = Report::new(args.flag_or("out", "results"))?;
    let which = args.positional.first().map(String::as_str).unwrap_or("all");
    if which == "all" {
        for n in ALL_TABLES {
            run_table(n, &mut r)?;
        }
    } else {
        run_table(which, &mut r)?;
    }
    r.finish(&format!("table{which}.md"))?;
    Ok(())
}

fn cmd_bench_all(args: &Args) -> Result<()> {
    let mut r = Report::new(args.flag_or("out", "results"))?;
    let max_seq = args.usize_flag("max-seq", 131072);
    r.line(&format!(
        "# MMEE paper reproduction run — {} candidates in the pruned offline table",
        MmeeEngine::query().num_candidates()
    ));
    for n in ALL_FIGS {
        run_fig(n, &mut r, max_seq)?;
    }
    for n in ALL_TABLES {
        run_table(n, &mut r)?;
    }
    r.finish("summary.md")?;
    println!("\nwrote {}", r.out_dir.join("summary.md").display());
    Ok(())
}
