//! PJRT client wrapper: compile-once executable cache + typed execution
//! of the two artifact kinds (full surfaces / objective reduction).
//!
//! The real client needs rust XLA/PJRT bindings (an `xla` crate) that
//! are not part of the offline build; it is gated behind the `pjrt`
//! cargo feature. Without the feature, the stub [`Runtime`] reports
//! [`MmeeError::Backend`] from `new()` so callers (the `xla` eval
//! backend, the CLI `--backend xla` path) degrade gracefully to the
//! native evaluator.

/// Outputs of the `full` artifact (padded bucket shapes, row-major C×T).
#[derive(Debug, Clone)]
pub struct FullOutput {
    pub c: usize,
    pub t: usize,
    pub energy: Vec<f32>,
    pub latency: Vec<f32>,
    pub da: Vec<f32>,
    pub bs: Vec<f32>,
}

/// Outputs of the `reduce` artifact: flat argmins over the C×T surface.
#[derive(Debug, Clone, Copy)]
pub struct ReduceOutput {
    pub min_energy: f32,
    pub arg_energy: usize,
    pub min_latency: f32,
    pub arg_latency: usize,
    pub min_edp: f32,
    pub arg_edp: usize,
}

#[cfg(not(feature = "pjrt"))]
mod imp {
    use super::{FullOutput, ReduceOutput};
    use crate::config::HwVector;
    use crate::error::{MmeeError, Result};
    use crate::runtime::artifacts::{ArtifactEntry, Manifest};

    fn unavailable() -> MmeeError {
        MmeeError::Backend(
            "PJRT runtime unavailable: this build has no XLA bindings; \
             rebuild with `--features pjrt` (vendored `xla` crate) and \
             run `make artifacts`, or use the native backend"
                .into(),
        )
    }

    /// Stub runtime for builds without the `pjrt` feature.
    pub struct Runtime {
        pub manifest: Manifest,
    }

    impl Runtime {
        pub fn new() -> Result<Runtime> {
            Err(unavailable())
        }

        pub fn run_full(
            &self,
            _entry: &ArtifactEntry,
            _qexp: &[f32],
            _coef: &[f32],
            _lnb: &[f32],
            _hw: &HwVector,
        ) -> Result<FullOutput> {
            Err(unavailable())
        }

        pub fn run_reduce(
            &self,
            _entry: &ArtifactEntry,
            _qexp: &[f32],
            _coef: &[f32],
            _lnb: &[f32],
            _hw: &HwVector,
        ) -> Result<ReduceOutput> {
            Err(unavailable())
        }

        pub fn platform(&self) -> String {
            "unavailable".into()
        }
    }
}

#[cfg(feature = "pjrt")]
mod imp {
    use std::collections::HashMap;
    use std::sync::Mutex;

    use super::{FullOutput, ReduceOutput};
    use crate::config::HwVector;
    use crate::error::{MmeeError, Result};
    use crate::model::terms::{NUM_FEATURES, NUM_SLOTS};
    use crate::runtime::artifacts::{ArtifactEntry, Manifest};

    fn backend_err(msg: impl std::fmt::Display) -> MmeeError {
        MmeeError::Backend(msg.to_string())
    }

    fn ensure(cond: bool, what: &str) -> Result<()> {
        if cond {
            Ok(())
        } else {
            Err(backend_err(what))
        }
    }

    pub struct Runtime {
        pub manifest: Manifest,
        client: xla::PjRtClient,
        execs: Mutex<HashMap<String, &'static xla::PjRtLoadedExecutable>>,
    }

    impl Runtime {
        pub fn new() -> Result<Runtime> {
            let manifest = Manifest::discover()?;
            let client = xla::PjRtClient::cpu()
                .map_err(|e| backend_err(format!("PJRT cpu client: {e}")))?;
            Ok(Runtime { manifest, client, execs: Mutex::new(HashMap::new()) })
        }

        /// Compile (once) and cache the executable for an artifact.
        /// Executables are leaked intentionally: they live for the process
        /// lifetime and sidestep non-`Clone` handle plumbing.
        fn executable(
            &self,
            entry: &ArtifactEntry,
        ) -> Result<&'static xla::PjRtLoadedExecutable> {
            let key = entry.file.display().to_string();
            let mut execs = self.execs.lock().unwrap();
            if let Some(e) = execs.get(&key) {
                return Ok(e);
            }
            let proto = xla::HloModuleProto::from_text_file(&entry.file)
                .map_err(|e| backend_err(format!("loading {}: {e}", entry.file.display())))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| backend_err(format!("compiling {}: {e}", entry.file.display())))?;
            let leaked: &'static xla::PjRtLoadedExecutable = Box::leak(Box::new(exe));
            execs.insert(key, leaked);
            Ok(leaked)
        }

        fn make_inputs(
            entry: &ArtifactEntry,
            qexp: &[f32],
            coef: &[f32],
            lnb: &[f32],
            hw: &HwVector,
        ) -> Result<[xla::Literal; 4]> {
            let (c, t) = (entry.c, entry.t);
            ensure(qexp.len() == c * NUM_SLOTS * NUM_FEATURES, "qexp shape")?;
            ensure(coef.len() == c * NUM_SLOTS, "coef shape")?;
            ensure(lnb.len() == NUM_FEATURES * t, "lnb shape")?;
            let q = xla::Literal::vec1(qexp)
                .reshape(&[c as i64, NUM_SLOTS as i64, NUM_FEATURES as i64])
                .map_err(|e| backend_err(format!("qexp reshape: {e}")))?;
            let cf = xla::Literal::vec1(coef)
                .reshape(&[c as i64, NUM_SLOTS as i64])
                .map_err(|e| backend_err(format!("coef reshape: {e}")))?;
            let b = xla::Literal::vec1(lnb)
                .reshape(&[NUM_FEATURES as i64, t as i64])
                .map_err(|e| backend_err(format!("lnb reshape: {e}")))?;
            let hwv = xla::Literal::vec1(&hw.to_f32_array()[..]);
            Ok([q, cf, b, hwv])
        }

        /// Execute the `full` artifact for one padded bucket.
        pub fn run_full(
            &self,
            entry: &ArtifactEntry,
            qexp: &[f32],
            coef: &[f32],
            lnb: &[f32],
            hw: &HwVector,
        ) -> Result<FullOutput> {
            let exe = self.executable(entry)?;
            let inputs = Self::make_inputs(entry, qexp, coef, lnb, hw)?;
            let result = exe
                .execute::<xla::Literal>(&inputs)
                .map_err(|e| backend_err(format!("execute full: {e}")))?;
            let tuple = result[0][0]
                .to_literal_sync()
                .map_err(|e| backend_err(format!("fetch: {e}")))?
                .to_tuple()
                .map_err(|e| backend_err(format!("untuple: {e}")))?;
            ensure(tuple.len() == 4, "full artifact returns 4 outputs")?;
            let mut vecs = tuple.into_iter().map(|l| {
                l.to_vec::<f32>().map_err(|e| backend_err(format!("to_vec: {e}")))
            });
            Ok(FullOutput {
                c: entry.c,
                t: entry.t,
                energy: vecs.next().unwrap()?,
                latency: vecs.next().unwrap()?,
                da: vecs.next().unwrap()?,
                bs: vecs.next().unwrap()?,
            })
        }

        /// Execute the `reduce` artifact for one padded bucket.
        pub fn run_reduce(
            &self,
            entry: &ArtifactEntry,
            qexp: &[f32],
            coef: &[f32],
            lnb: &[f32],
            hw: &HwVector,
        ) -> Result<ReduceOutput> {
            let exe = self.executable(entry)?;
            let inputs = Self::make_inputs(entry, qexp, coef, lnb, hw)?;
            let result = exe
                .execute::<xla::Literal>(&inputs)
                .map_err(|e| backend_err(format!("execute reduce: {e}")))?;
            let tuple = result[0][0]
                .to_literal_sync()
                .map_err(|e| backend_err(format!("fetch: {e}")))?
                .to_tuple()
                .map_err(|e| backend_err(format!("untuple: {e}")))?;
            ensure(tuple.len() == 6, "reduce artifact returns 6 outputs")?;
            let scalar_f = |l: &xla::Literal| -> Result<f32> {
                Ok(l.to_vec::<f32>().map_err(backend_err)?[0])
            };
            let scalar_i = |l: &xla::Literal| -> Result<usize> {
                Ok(l.to_vec::<i32>().map_err(backend_err)?[0] as usize)
            };
            Ok(ReduceOutput {
                min_energy: scalar_f(&tuple[0])?,
                arg_energy: scalar_i(&tuple[1])?,
                min_latency: scalar_f(&tuple[2])?,
                arg_latency: scalar_i(&tuple[3])?,
                min_edp: scalar_f(&tuple[4])?,
                arg_edp: scalar_i(&tuple[5])?,
            })
        }

        pub fn platform(&self) -> String {
            self.client.platform_name()
        }
    }
}

pub use imp::Runtime;

#[cfg(test)]
mod tests {
    use super::*;

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn stub_runtime_reports_backend_error() {
        let err = Runtime::new().unwrap_err();
        assert_eq!(err.kind(), "backend");
        assert!(err.to_string().contains("pjrt"), "{err}");
    }

    /// Smoke: load + compile + execute the small bucket with a trivial
    /// single-monomial query; verify against the closed form.
    #[cfg(feature = "pjrt")]
    #[test]
    fn full_artifact_roundtrip() {
        use crate::config::HwVector;
        use crate::model::terms::{NUM_FEATURES, NUM_SLOTS};
        let Ok(rt) = Runtime::new() else {
            eprintln!("artifacts not built; skipping");
            return;
        };
        let entry = rt.manifest.pick("full", 1, 1).unwrap().clone();
        let (c, t) = (entry.c, entry.t);
        let mut qexp = vec![0.0f32; c * NUM_SLOTS * NUM_FEATURES];
        let mut coef = vec![0.0f32; c * NUM_SLOTS];
        // Candidate 0, slot 12 (DA segment): monomial i_d * i_g.
        qexp[12 * NUM_FEATURES] = 1.0; // i_d
        qexp[12 * NUM_FEATURES + 4] = 1.0; // i_g
        coef[12] = 1.0;
        // lnb: tiling column 0 with i_d = 8, i_g = 64; rest 1.
        let mut lnb = vec![0.0f32; NUM_FEATURES * t];
        lnb[0] = (8.0f32).ln();
        lnb[4 * t] = (64.0f32).ln();
        let hw = HwVector {
            e_dram: 1.0,
            e_buf: 0.0,
            e_mac: 0.0,
            e_sfu: 0.0,
            e_bs: 0.0,
            sec_per_word: 1.0,
            sec_per_cycle: 0.0,
            capacity_words: 1e9,
        };
        let out = rt.run_full(&entry, &qexp, &coef, &lnb, &hw).unwrap();
        // energy[0,0] = e_dram * DA = 8 * 64 = 512.
        assert!((out.energy[0] - 512.0).abs() < 1e-2, "{}", out.energy[0]);
        assert!((out.da[0] - 512.0).abs() < 1e-2);
        // Other candidates: zero DA, zero energy (feasible, bs=0).
        assert_eq!(out.energy[t], 0.0);
    }
}
