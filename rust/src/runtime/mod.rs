//! PJRT runtime: load the AOT-compiled JAX/Pallas evaluation graphs
//! (`artifacts/*.hlo.txt`) and execute them from the rust hot path.
//!
//! Python runs only at build time (`make artifacts`); this module is the
//! entire request-path interface to the compiled L1/L2 stack. The real
//! PJRT client is gated behind the `pjrt` cargo feature (see
//! [`client`]); default builds get a stub that reports
//! `MmeeError::Backend` and leave the native evaluator in charge.

pub mod artifacts;
pub mod client;

pub use artifacts::{ArtifactEntry, Manifest};
pub use client::{FullOutput, ReduceOutput, Runtime};
