//! Artifact discovery + manifest validation.
//!
//! `manifest.json` (written by `python/compile/aot.py`) records the slot
//! layout the artifacts were compiled against; we refuse to run if it
//! disagrees with this crate's encoder constants — a drifted layout would
//! silently mis-evaluate every mapping.

use std::path::{Path, PathBuf};

use crate::error::{MmeeError, Result};
use crate::model::terms::{seg, NUM_FEATURES, NUM_SLOTS};
use crate::util::json::Json;

fn parse_err(msg: impl Into<String>) -> MmeeError {
    MmeeError::Parse(msg.into())
}

pub const LAYOUT_VERSION: usize = 4;

#[derive(Debug, Clone)]
pub struct ArtifactEntry {
    pub kind: String,
    pub bucket: String,
    pub file: PathBuf,
    pub c: usize,
    pub t: usize,
}

#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub entries: Vec<ArtifactEntry>,
}

impl Manifest {
    /// Default search order: `$MMEE_ARTIFACTS`, `./artifacts`,
    /// `<crate root>/artifacts`.
    pub fn discover() -> Result<Manifest> {
        let mut cands: Vec<PathBuf> = Vec::new();
        if let Ok(p) = std::env::var("MMEE_ARTIFACTS") {
            cands.push(PathBuf::from(p));
        }
        cands.push(PathBuf::from("artifacts"));
        cands.push(PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts"));
        for dir in cands {
            if dir.join("manifest.json").exists() {
                return Self::load(&dir);
            }
        }
        Err(MmeeError::Io("no artifacts found; run `make artifacts` first".into()))
    }

    pub fn load(dir: &Path) -> Result<Manifest> {
        let text = std::fs::read_to_string(dir.join("manifest.json")).map_err(|e| {
            MmeeError::Io(format!("reading {}/manifest.json: {e}", dir.display()))
        })?;
        let j = Json::parse(&text)
            .map_err(|e| parse_err(format!("parsing manifest.json: {e}")))?;
        validate_layout(&j)?;
        let mut entries = Vec::new();
        for a in j
            .get("artifacts")
            .and_then(Json::as_arr)
            .ok_or_else(|| parse_err("manifest missing artifacts"))?
        {
            let get = |k: &str| -> Result<&Json> {
                a.get(k).ok_or_else(|| parse_err(format!("artifact entry missing '{k}'")))
            };
            entries.push(ArtifactEntry {
                kind: get("kind")?.as_str().unwrap_or_default().to_string(),
                bucket: get("bucket")?.as_str().unwrap_or_default().to_string(),
                file: dir.join(get("file")?.as_str().unwrap_or_default()),
                c: get("C")?.as_usize().unwrap_or(0),
                t: get("T")?.as_usize().unwrap_or(0),
            });
        }
        Ok(Manifest { dir: dir.to_path_buf(), entries })
    }

    /// The smallest bucket of `kind` whose (C, T) covers the request, or
    /// the largest bucket otherwise (the caller chunks).
    pub fn pick(&self, kind: &str, _c: usize, t: usize) -> Option<&ArtifactEntry> {
        let mut of_kind: Vec<&ArtifactEntry> =
            self.entries.iter().filter(|e| e.kind == kind).collect();
        of_kind.sort_by_key(|e| e.c * e.t);
        of_kind
            .iter()
            .find(|e| e.t >= t)
            .copied()
            .or_else(|| of_kind.last().copied())
    }
}

fn validate_layout(j: &Json) -> Result<()> {
    let expect = |cond: bool, what: &str| -> Result<()> {
        if cond {
            Ok(())
        } else {
            Err(parse_err(format!(
                "artifact layout mismatch: {what}; re-run `make artifacts`"
            )))
        }
    };
    expect(
        j.get("layout_version").and_then(Json::as_usize) == Some(LAYOUT_VERSION),
        "layout_version",
    )?;
    expect(j.get("num_slots").and_then(Json::as_usize) == Some(NUM_SLOTS), "num_slots")?;
    expect(
        j.get("num_features").and_then(Json::as_usize) == Some(NUM_FEATURES),
        "num_features",
    )?;
    let segs = j.get("segments").ok_or_else(|| parse_err("manifest missing segments"))?;
    let check_seg = |name: &str, s: (usize, usize)| -> Result<()> {
        let got = segs
            .get(name)
            .and_then(Json::as_arr)
            .ok_or_else(|| parse_err(format!("segment {name} missing")))?;
        expect(
            got.len() == 2
                && got[0].as_usize() == Some(s.0)
                && got[1].as_usize() == Some(s.1),
            &format!("segment {name}"),
        )
    };
    check_seg("bs1", seg::BS1)?;
    check_seg("bs2", seg::BS2)?;
    check_seg("da", seg::DA)?;
    check_seg("br", seg::BR)?;
    check_seg("mac", seg::MAC)?;
    check_seg("smx", seg::SMX)?;
    check_seg("cl1", seg::CL1)?;
    check_seg("cl2", seg::CL2)?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn discover_and_validate_if_built() {
        match Manifest::discover() {
            Ok(m) => {
                assert!(m.entries.len() >= 4);
                assert!(m.pick("full", 1000, 300).is_some());
                assert!(m.pick("reduce", 1, 1).is_some());
                let small = m.pick("full", 10, 10).unwrap();
                assert!(small.t >= 10);
                for e in &m.entries {
                    assert!(e.file.exists(), "{} missing", e.file.display());
                }
            }
            Err(e) => {
                // Artifacts not built in this environment; fine for unit runs.
                assert!(e.to_string().contains("make artifacts"), "{e}");
            }
        }
    }

    #[test]
    fn layout_mismatch_is_rejected() {
        let j = Json::parse(r#"{"layout_version": 1}"#).unwrap();
        assert!(validate_layout(&j).is_err());
    }
}
