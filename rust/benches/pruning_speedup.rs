//! §VII-I.4: search runtime with vs without offline symbolic pruning,
//! and proof that the optimum is unchanged.

use mmee::config::presets;
use mmee::encode::QueryMatrix;
use mmee::loopnest::dims::STATIONARIES;
use mmee::loopnest::Candidate;
use mmee::search::{MmeeEngine, Objective};
use mmee::symbolic::prune::{deduped_unpruned, pruned_table};
use mmee::util::bench::Bench;

fn main() {
    let engine = MmeeEngine::native();
    let accel = presets::accel1();
    let w = presets::bert_base(512);

    let pt = pruned_table();
    println!(
        "offline table: raw {}/class, distinct [{}, {}], survivors [{}, {}]",
        pt.raw_per_class,
        pt.distinct_per_class[0],
        pt.distinct_per_class[1],
        pt.classes[0].len(),
        pt.classes[1].len()
    );

    let mut unpruned = Vec::new();
    for rec in [false, true] {
        for e in deduped_unpruned(rec) {
            for sm1 in STATIONARIES {
                for sm2 in STATIONARIES {
                    unpruned.push(Candidate { order: e.order, levels: e.levels, sm1, sm2 });
                }
            }
        }
    }
    let q_unpruned = QueryMatrix::build(unpruned);
    let q_pruned = MmeeEngine::query();
    println!(
        "rows: pruned {} vs unpruned {}",
        q_pruned.num_candidates(),
        q_unpruned.num_candidates()
    );

    let mut bench = Bench::new();
    let p = bench.run("optimize with pruned table", || {
        engine.optimize(&w, &accel, Objective::Energy).unwrap().metrics.energy
    });
    let u = bench.run("optimize with unpruned table", || {
        engine
            .optimize_with_candidates(&w, &accel, Objective::Energy, &q_unpruned)
            .unwrap()
            .metrics
            .energy
    });
    let ep = engine.optimize(&w, &accel, Objective::Energy).unwrap().metrics.energy;
    let eu = engine
        .optimize_with_candidates(&w, &accel, Objective::Energy, &q_unpruned)
        .unwrap()
        .metrics
        .energy;
    assert!((ep - eu).abs() <= 1e-9 * eu, "pruning changed the optimum");
    println!(
        "pruning speedup: {:.1}x with identical optimum ({:.6} mJ). paper: 347x/221x",
        u.median.as_secs_f64() / p.median.as_secs_f64(),
        ep * 1e3
    );
}
