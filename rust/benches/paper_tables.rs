//! End-to-end timing of the per-table/figure harness entries — the
//! "runtime" rows of §VII-C/D at our scale (one bench per paper table,
//! timing the full regeneration including all baselines).

use mmee::report::{figures, tables, Report};
use mmee::util::bench::Bench;

fn main() {
    let tmp = std::env::temp_dir().join("mmee_bench_tables");
    let mut bench = Bench::new();

    let mut r = Report::new(&tmp).unwrap();
    bench.once("table1 (absolute E/L, 2 accels x 9 workloads)", || {
        tables::table1(&mut r).unwrap()
    });
    bench.once("table3 (3 hardware designs incl. TileFlow GA+MCTS)", || {
        tables::table3(&mut r).unwrap()
    });
    bench.once("table4 (conv chains + two-GEMMs)", || {
        tables::table4(&mut r).unwrap()
    });
    bench.once("fig16 (DA-vs-buffer fronts, 4 mappers)", || {
        figures::fig16(&mut r).unwrap()
    });
    bench.once("fig24 (decision-element ablation)", || {
        figures::fig24(&mut r).unwrap()
    });
    println!("\nbench artifacts in {}", tmp.display());
}
