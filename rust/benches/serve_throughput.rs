//! Serving-path throughput: sequential vs batched vs concurrent
//! handling of a mixed preset trace (ROADMAP: "measure hit rates under
//! real DSE traces"), plus a threads-vs-epoll front-end A/B under an
//! adversarial cold-cache trace with idle-connection ballast. Emits
//! `BENCH_serve.json` so the serving trajectory is machine-trackable
//! across PRs.
//!
//! The trace repeats 3 distinct (workload, accel) surfaces across 24
//! requests with rotating objectives — the pipelined-compiler shape.
//! * `sequential`  — one request per line through `serve_lines`;
//! * `batched`     — the same 24 requests as ONE JSON-array line:
//!                   shared surfaces collapse to one pass per group;
//! * `concurrent`  — per-line serving with a worker pool sharing one
//!                   `Send + Sync` engine.
//!
//! The front-end A/B is the tail-latency experiment: keep-alive ballast
//! connections sit idle while client threads hammer cold-key requests
//! through short-lived connections. On the thread-per-connection front
//! end the ballast PINS workers, so active requests queue behind idle
//! sockets; the epoll front end parks the ballast for free. `p99_ms`
//! per mode plus a `p99_improvement` factor (target 1.2x) land in
//! `BENCH_serve.json`.
//!
//! Each mode runs on a fresh engine (cold caches) so the printed
//! boundary/plan hit rates describe the trace, not the harness.
//! `--smoke` (or `--test`) shrinks every section to small surfaces and
//! still writes the full JSON schema — CI runs it so the schema cannot
//! rot unnoticed.

use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpStream};
use std::time::{Duration, Instant};

use mmee::coordinator::{service, serve_tcp_with, NetMode};
use mmee::search::MmeeEngine;
use mmee::util::bench::Bench;
use mmee::util::json::Json;

fn trace_lines(small: bool) -> Vec<String> {
    let surfaces: &[&str] = if small {
        &[
            r#""workload": "mlp", "accel": "accel1""#,
            r#""workload": "bert-base", "seq": 256, "accel": "accel1""#,
            r#""workload": "cc1", "accel": "accel1""#,
        ]
    } else {
        &[
            r#""workload": "bert-base", "seq": 512, "accel": "accel1""#,
            r#""workload": "bert-base", "seq": 512, "accel": "accel2""#,
            r#""workload": "cc1", "accel": "accel1""#,
        ]
    };
    let objectives = ["energy", "latency", "edp"];
    let n = if small { 12 } else { 24 };
    (0..n)
        .map(|i| {
            let spec = surfaces[i % surfaces.len()];
            let obj = objectives[(i / surfaces.len()) % objectives.len()];
            format!(r#"{{{spec}, "objective": "{obj}"}}"#)
        })
        .collect()
}

/// Nearest-rank percentile over an ascending-sorted sample set.
fn percentile(sorted: &[Duration], p: f64) -> Duration {
    sorted[((sorted.len() - 1) as f64 * p).round() as usize]
}

fn report_rates(engine: &MmeeEngine, served: usize, secs: f64) {
    let (ph, pm) = engine.plan_cache_stats();
    let (bh, bm) = engine.boundary_cache_stats();
    // Weighted view: hits and (miss-driven) inserts in feature slots,
    // so the rate reads as "fraction of boundary words served from
    // cache instead of rebuilt" — big surfaces count for more.
    let (hw, pw) = engine.boundary_cache_weight_stats();
    println!(
        "    {:.1} req/s; plan cache {ph}/{} hits ({:.0}%), boundary cache {bh}/{} hits \
         (weighted: {hw}/{} slots from cache = {:.0}%; {} cold builds)",
        served as f64 / secs,
        ph + pm,
        100.0 * ph as f64 / ((ph + pm).max(1)) as f64,
        bh + bm,
        hw + pw,
        100.0 * hw as f64 / ((hw + pw).max(1)) as f64,
        engine.boundary_build_count(),
    );
}

/// One short-lived client exchange: connect, send one line, read one
/// response, close (the drop is the half-close).
fn request(addr: SocketAddr, line: &str) -> String {
    let mut conn = TcpStream::connect(addr).expect("connect");
    conn.set_read_timeout(Some(Duration::from_secs(120))).expect("read timeout");
    writeln!(conn, "{line}").expect("write request");
    let mut resp = String::new();
    BufReader::new(conn).read_line(&mut resp).expect("read response");
    resp
}

/// The key every ballast connection asks for once (prewarmed, so the
/// ballast costs one cache hit each — its job is to *idle*).
const BALLAST_LINE: &str = r#"{"workload": "mlp", "accel": "accel1"}"#;

/// Threads-vs-epoll A/B: `ballast` keep-alive connections idle while
/// `clients` threads drive cold-key requests over short-lived
/// connections. Returns the `front_end_ab` JSON object.
fn front_end_ab(smoke: bool) -> Json {
    let (ballast_n, clients, per_client) = if smoke { (4, 2, 4) } else { (6, 4, 16) };
    let workers = 8usize;
    let total_conns = 1 + ballast_n + clients * per_client;
    let total_requests = total_conns; // one request per connection
    println!(
        "\nfront-end A/B: {ballast_n} idle keep-alive conns, {clients} clients x \
         {per_client} cold-key requests, {workers} workers"
    );
    let modes: &[NetMode] = if NetMode::epoll_supported() {
        &[NetMode::Threads, NetMode::Epoll]
    } else {
        &[NetMode::Threads]
    };
    // Every request names a distinct seq, so every plan is a cold
    // surface build (`mlp` would ignore `seq` and collapse to one key).
    let seq_base = if smoke { 64 } else { 200 };
    let cold_line = move |i: usize| {
        format!(r#"{{"workload": "bert-base", "seq": {}, "accel": "accel1"}}"#, seq_base + i)
    };
    let mut rows = Vec::new();
    let mut p99_by_mode = Vec::new();
    for &mode in modes {
        let engine = MmeeEngine::native();
        let (tx, rx) = std::sync::mpsc::channel();
        let server = std::thread::spawn(move || {
            serve_tcp_with(&engine, "127.0.0.1:0", Some(total_conns), workers, mode, |a| {
                tx.send(a).unwrap()
            })
            .expect("serve_tcp_with")
        });
        let addr = rx.recv().expect("server ready");
        let warm = request(addr, BALLAST_LINE);
        assert!(warm.contains("energy_j"), "prewarm failed: {warm}");
        // Keep-alive ballast: one warm request each, then pure idle.
        // On the threads front end this pins a worker per connection.
        let ballast: Vec<TcpStream> = (0..ballast_n)
            .map(|_| {
                let mut conn = TcpStream::connect(addr).expect("ballast connect");
                conn.set_read_timeout(Some(Duration::from_secs(120))).expect("read timeout");
                writeln!(conn, "{BALLAST_LINE}").expect("ballast request");
                let mut resp = String::new();
                BufReader::new(conn.try_clone().expect("clone"))
                    .read_line(&mut resp)
                    .expect("ballast response");
                assert!(resp.contains("energy_j"), "ballast request failed: {resp}");
                conn
            })
            .collect();
        let t0 = Instant::now();
        let mut lat: Vec<Duration> = std::thread::scope(|scope| {
            let cold_line = &cold_line;
            let handles: Vec<_> = (0..clients)
                .map(|c| {
                    scope.spawn(move || {
                        let mut samples = Vec::with_capacity(per_client);
                        for k in 0..per_client {
                            let line = cold_line(c * per_client + k);
                            let t = Instant::now();
                            let resp = request(addr, &line);
                            samples.push(t.elapsed());
                            assert!(resp.contains("energy_j"), "cold plan failed: {resp}");
                        }
                        samples
                    })
                })
                .collect();
            handles.into_iter().flat_map(|h| h.join().expect("client thread")).collect()
        });
        let secs = t0.elapsed().as_secs_f64();
        drop(ballast);
        let served = server.join().expect("server thread");
        assert_eq!(served, total_requests, "{} front end dropped requests", mode.name());
        lat.sort();
        let (p50, p99) = (percentile(&lat, 0.50), percentile(&lat, 0.99));
        let req_per_s = (clients * per_client) as f64 / secs.max(1e-12);
        println!(
            "  {:<7}  p50 {p50:.3?}  p99 {p99:.3?}  ({req_per_s:.1} active req/s)",
            mode.name()
        );
        p99_by_mode.push(p99.as_secs_f64() * 1e3);
        rows.push(Json::obj(vec![
            ("net", Json::str(mode.name())),
            ("p50_ms", Json::num(p50.as_secs_f64() * 1e3)),
            ("p99_ms", Json::num(p99.as_secs_f64() * 1e3)),
            ("req_per_s", Json::num(req_per_s)),
            ("served", Json::num(served as f64)),
        ]));
    }
    const P99_TARGET: f64 = 1.2;
    let (improvement, met) = match p99_by_mode.as_slice() {
        [threads_p99, epoll_p99] => {
            let x = threads_p99 / epoll_p99.max(1e-9);
            println!(
                "  p99 improvement threads/epoll: {x:.2}x (target {P99_TARGET:.1}x: {})",
                if x >= P99_TARGET { "met" } else { "not met" }
            );
            (Json::num(x), x >= P99_TARGET)
        }
        // Off-Linux there is nothing to compare against.
        _ => (Json::Null, false),
    };
    Json::obj(vec![
        ("ballast_conns", Json::num(ballast_n as f64)),
        ("clients", Json::num(clients as f64)),
        ("met", Json::Bool(met)),
        ("p99_improvement", improvement),
        ("p99_target", Json::num(P99_TARGET)),
        ("requests_per_client", Json::num(per_client as f64)),
        ("rows", Json::arr(rows)),
        ("workers", Json::num(workers as f64)),
    ])
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke" || a == "--test");
    let lines = trace_lines(smoke);
    let per_line = lines.join("\n");
    let as_batch = format!("[{}]", lines.join(","));
    println!("trace: {} requests over 3 distinct (workload, accel) surfaces", lines.len());

    let mut bench = Bench::new();

    let engine = MmeeEngine::native();
    let (seq, n_seq) = bench.once("serve_lines (sequential, cold)", || {
        let mut out = Vec::new();
        service::serve_lines(&engine, per_line.as_bytes(), &mut out).unwrap()
    });
    report_rates(&engine, n_seq, seq.median.as_secs_f64());

    let engine = MmeeEngine::native();
    let (bat, n_bat) = bench.once("serve_lines (one batch line, cold)", || {
        let mut out = Vec::new();
        service::serve_lines(&engine, as_batch.as_bytes(), &mut out).unwrap()
    });
    report_rates(&engine, n_bat, bat.median.as_secs_f64());
    assert_eq!(n_seq, n_bat, "both modes answer the whole trace");

    let workers = mmee::coordinator::pool::default_workers().min(8);
    let engine = MmeeEngine::native();
    let (conc, n_conc) = bench.once(
        &format!("serve_lines_concurrent ({workers} workers, cold)"),
        || {
            let mut out = Vec::new();
            service::serve_lines_concurrent(&engine, per_line.as_bytes(), &mut out, workers)
                .unwrap()
        },
    );
    report_rates(&engine, n_conc, conc.median.as_secs_f64());

    // Warm repeat: the pipelined-compiler steady state is pure cache.
    let (warm, n_warm) = bench.once("serve_lines (sequential, warm cache)", || {
        let mut out = Vec::new();
        service::serve_lines(&engine, per_line.as_bytes(), &mut out).unwrap()
    });
    report_rates(&engine, n_warm, warm.median.as_secs_f64());

    if !smoke {
        // Per-request latency distribution: every line served on its
        // own, so the spread is visible, not just the aggregate rate.
        // Cold pass first (surface builds dominate the tail), then the
        // warm steady state the cluster front-end cares about.
        let engine = MmeeEngine::native();
        for pass in ["cold", "warm"] {
            let mut lat = Vec::with_capacity(lines.len());
            let t0 = Instant::now();
            for line in &lines {
                let t = Instant::now();
                let mut out = Vec::new();
                service::serve_lines(&engine, line.as_bytes(), &mut out).unwrap();
                lat.push(t.elapsed());
            }
            let total = t0.elapsed().as_secs_f64();
            lat.sort();
            println!(
                "per-request latency ({pass}): p50 {:.3?}  p99 {:.3?}  max {:.3?}  ({:.1} req/s)",
                percentile(&lat, 0.50),
                percentile(&lat, 0.99),
                lat.last().unwrap(),
                lines.len() as f64 / total.max(1e-12),
            );
        }

        // Weight-bounded boundary cache (ROADMAP "cache policy" item):
        // repeat optimize() rounds over the trace's surfaces — optimize
        // bypasses the plan cache, so boundary retention differences
        // show directly in the weighted hit rate ("fraction of boundary
        // words served from cache"). The 1k-slot budget admits nothing:
        // every round pays cold builds, the weighted floor of this
        // trace.
        use mmee::config::presets;
        use mmee::search::Objective;
        let surfaces = [
            (presets::bert_base(512), presets::accel1()),
            (presets::bert_base(512), presets::accel2()),
            (presets::cc1(), presets::accel1()),
        ];
        for (label, engine) in [
            ("unbounded weight budget", MmeeEngine::native()),
            ("1k-slot weight budget", MmeeEngine::builder().boundary_weight_budget(1_000).build()),
        ] {
            let (s, n) = bench.once(&format!("optimize x2 rounds ({label})"), || {
                let mut served = 0usize;
                for _ in 0..2 {
                    for (w, a) in &surfaces {
                        engine.optimize(w, a, Objective::Energy).unwrap();
                        served += 1;
                    }
                }
                served
            });
            report_rates(&engine, n, s.median.as_secs_f64());
        }
        // Decode trace (dynamic shapes): an autoregressive client
        // re-plans after every generated token, so L advances by one
        // per request and NO line repeats a surface — the plan cache
        // never hits. Serving the lines pays a cold build + pass per
        // shape; `plan_sweep` chains delta builds and incumbent-seeded
        // passes over the same shapes.
        use mmee::search::{MappingRequest, SweepSpec};
        let decode: Vec<String> = (0..16)
            .map(|i| {
                format!(
                    r#"{{"workload": "bert-base", "seq": {}, "objective": "latency", "accel": "accel1"}}"#,
                    512 + i
                )
            })
            .collect();
        let decode_text = decode.join("\n");
        let engine = MmeeEngine::native();
        let (line_by_line, n_dec) = bench.once("decode trace (16 steps, per-line)", || {
            let mut out = Vec::new();
            service::serve_lines(&engine, decode_text.as_bytes(), &mut out).unwrap()
        });
        report_rates(&engine, n_dec, line_by_line.median.as_secs_f64());
        let engine = MmeeEngine::native();
        let base = MappingRequest::preset("bert-base", 512, "accel1", Objective::Latency);
        let spec = SweepSpec::seq((512..528).collect());
        let (swept, _) = bench.once("decode trace (16 steps, plan_sweep)", || {
            engine.plan_sweep(&base, &spec).unwrap().plans.len()
        });
        println!(
            "    decode warm-start: plan_sweep vs per-line serving: {:.2}x",
            line_by_line.median.as_secs_f64() / swept.median.as_secs_f64().max(1e-12)
        );

        // Deadline discipline (ROADMAP "tail-latency-grade serving"):
        // the mixed trace again, now with per-request budgets — every
        // fourth line gets a zero budget (shed at admission with a
        // structured deadline_exceeded, no surface work), the rest a
        // generous one (deadline met). The met/degraded/shed split is
        // printed so a run's deadline behavior is visible at a glance.
        let engine = MmeeEngine::native();
        let deadlined: Vec<String> = lines
            .iter()
            .enumerate()
            .map(|(i, l)| {
                let ms = if i % 4 == 0 { 0 } else { 600_000u64 };
                format!(r#"{}, "deadline_ms": {ms}}}"#, &l[..l.len() - 1])
            })
            .collect();
        let deadline_text = deadlined.join("\n");
        let (dl, n_dl) = bench.once("serve_lines (deadline trace, cold)", || {
            let mut out = Vec::new();
            service::serve_lines(&engine, deadline_text.as_bytes(), &mut out).unwrap();
            let text = String::from_utf8(out).unwrap();
            let (mut met, mut degraded, mut shed) = (0usize, 0usize, 0usize);
            for line in text.lines() {
                let j = Json::parse(line).unwrap();
                if j.get("error").is_some() {
                    shed += 1;
                } else if j.get("degraded").is_some() {
                    degraded += 1;
                } else {
                    met += 1;
                }
            }
            println!("    deadlines: {met} met, {degraded} degraded, {shed} shed");
            met + degraded + shed
        });
        report_rates(&engine, n_dl, dl.median.as_secs_f64());

        // Anytime degradation, forced: a 2-tile-block cancellation
        // budget against a cold engine shows how much surface an
        // interrupted pass still covers (degraded results are never
        // memoized, so every repetition pays the same partial pass).
        use mmee::coordinator::CancelToken;
        let cold_engine = MmeeEngine::native();
        let anytime_req = MappingRequest::preset("bert-base", 512, "accel1", Objective::Energy);
        let _ = bench.once("plan_cancellable (2 tile-block budget, cold)", || {
            let token = CancelToken::after_checks(2);
            let plan = cold_engine.plan_cancellable(&anytime_req, Some(&token)).unwrap();
            assert!(plan.degraded, "a 2-block budget must degrade on a cold surface");
            println!(
                "    anytime: incumbent energy {:.3e} J after {} of {} tile blocks",
                plan.solution.metrics.energy,
                plan.stats.blocks_evaluated,
                plan.stats.blocks_evaluated + plan.stats.blocks_cancelled,
            );
            1usize
        });
    }

    println!(
        "\nbatched vs sequential (cold): {:.2}x  |  concurrent vs sequential (cold): {:.2}x",
        seq.median.as_secs_f64() / bat.median.as_secs_f64().max(1e-12),
        seq.median.as_secs_f64() / conc.median.as_secs_f64().max(1e-12),
    );

    let ab = front_end_ab(smoke);
    let report = Json::obj(vec![
        ("bench", Json::str("serve_throughput")),
        ("front_end_ab", ab),
        (
            "modes",
            Json::obj(vec![
                ("batched_s", Json::num(bat.median.as_secs_f64())),
                ("concurrent_s", Json::num(conc.median.as_secs_f64())),
                ("sequential_s", Json::num(seq.median.as_secs_f64())),
                ("warm_s", Json::num(warm.median.as_secs_f64())),
            ]),
        ),
        ("smoke", Json::Bool(smoke)),
        ("trace_requests", Json::num(lines.len() as f64)),
    ]);
    let text = format!("{report}\n");
    for key in [
        "front_end_ab",
        "ballast_conns",
        "net",
        "p50_ms",
        "p99_ms",
        "req_per_s",
        "p99_improvement",
        "p99_target",
        "met",
        "sequential_s",
        "warm_s",
    ] {
        assert!(text.contains(key), "BENCH_serve.json schema lost key {key}");
    }
    std::fs::write("BENCH_serve.json", &text).expect("write BENCH_serve.json");
    println!("wrote BENCH_serve.json{}", if smoke { "  [smoke ok]" } else { "" });
}
