//! Serving-path throughput: sequential vs batched vs concurrent
//! handling of a mixed preset trace (ROADMAP: "measure hit rates under
//! real DSE traces").
//!
//! The trace repeats 3 distinct (workload, accel) surfaces across 24
//! requests with rotating objectives — the pipelined-compiler shape.
//! * `sequential`  — one request per line through `serve_lines`;
//! * `batched`     — the same 24 requests as ONE JSON-array line:
//!                   shared surfaces collapse to one pass per group;
//! * `concurrent`  — per-line serving with a worker pool sharing one
//!                   `Send + Sync` engine.
//!
//! Each mode runs on a fresh engine (cold caches) so the printed
//! boundary/plan hit rates describe the trace, not the harness.

use mmee::coordinator::service;
use mmee::search::MmeeEngine;
use mmee::util::bench::Bench;

fn trace_lines() -> Vec<String> {
    let surfaces = [
        (r#""workload": "bert-base", "seq": 512, "accel": "accel1""#, "energy"),
        (r#""workload": "bert-base", "seq": 512, "accel": "accel2""#, "latency"),
        (r#""workload": "cc1", "accel": "accel1""#, "edp"),
    ];
    let objectives = ["energy", "latency", "edp"];
    let mut lines = Vec::new();
    for i in 0..24 {
        let (spec, _) = surfaces[i % surfaces.len()];
        let obj = objectives[(i / surfaces.len()) % objectives.len()];
        lines.push(format!(r#"{{{spec}, "objective": "{obj}"}}"#));
    }
    lines
}

/// Nearest-rank percentile over an ascending-sorted sample set.
fn percentile(sorted: &[std::time::Duration], p: f64) -> std::time::Duration {
    sorted[((sorted.len() - 1) as f64 * p).round() as usize]
}

fn report_rates(engine: &MmeeEngine, served: usize, secs: f64) {
    let (ph, pm) = engine.plan_cache_stats();
    let (bh, bm) = engine.boundary_cache_stats();
    // Weighted view: hits and (miss-driven) inserts in feature slots,
    // so the rate reads as "fraction of boundary words served from
    // cache instead of rebuilt" — big surfaces count for more.
    let (hw, pw) = engine.boundary_cache_weight_stats();
    println!(
        "    {:.1} req/s; plan cache {ph}/{} hits ({:.0}%), boundary cache {bh}/{} hits \
         (weighted: {hw}/{} slots from cache = {:.0}%; {} cold builds)",
        served as f64 / secs,
        ph + pm,
        100.0 * ph as f64 / ((ph + pm).max(1)) as f64,
        bh + bm,
        hw + pw,
        100.0 * hw as f64 / ((hw + pw).max(1)) as f64,
        engine.boundary_build_count(),
    );
}

fn main() {
    let lines = trace_lines();
    let per_line = lines.join("\n");
    let as_batch = format!("[{}]", lines.join(","));
    println!("trace: {} requests over 3 distinct (workload, accel) surfaces", lines.len());

    let mut bench = Bench::new();

    let engine = MmeeEngine::native();
    let (seq, n_seq) = bench.once("serve_lines (sequential, cold)", || {
        let mut out = Vec::new();
        service::serve_lines(&engine, per_line.as_bytes(), &mut out).unwrap()
    });
    report_rates(&engine, n_seq, seq.median.as_secs_f64());

    let engine = MmeeEngine::native();
    let (bat, n_bat) = bench.once("serve_lines (one batch line, cold)", || {
        let mut out = Vec::new();
        service::serve_lines(&engine, as_batch.as_bytes(), &mut out).unwrap()
    });
    report_rates(&engine, n_bat, bat.median.as_secs_f64());
    assert_eq!(n_seq, n_bat, "both modes answer the whole trace");

    let workers = mmee::coordinator::pool::default_workers().min(8);
    let engine = MmeeEngine::native();
    let (conc, n_conc) = bench.once(
        &format!("serve_lines_concurrent ({workers} workers, cold)"),
        || {
            let mut out = Vec::new();
            service::serve_lines_concurrent(&engine, per_line.as_bytes(), &mut out, workers)
                .unwrap()
        },
    );
    report_rates(&engine, n_conc, conc.median.as_secs_f64());

    // Warm repeat: the pipelined-compiler steady state is pure cache.
    let (warm, n_warm) = bench.once("serve_lines (sequential, warm cache)", || {
        let mut out = Vec::new();
        service::serve_lines(&engine, per_line.as_bytes(), &mut out).unwrap()
    });
    report_rates(&engine, n_warm, warm.median.as_secs_f64());

    // Per-request latency distribution: every line served on its own,
    // so the spread is visible, not just the aggregate rate. Cold pass
    // first (surface builds dominate the tail), then the warm steady
    // state the cluster front-end cares about.
    let engine = MmeeEngine::native();
    for pass in ["cold", "warm"] {
        let mut lat = Vec::with_capacity(lines.len());
        let t0 = std::time::Instant::now();
        for line in &lines {
            let t = std::time::Instant::now();
            let mut out = Vec::new();
            service::serve_lines(&engine, line.as_bytes(), &mut out).unwrap();
            lat.push(t.elapsed());
        }
        let total = t0.elapsed().as_secs_f64();
        lat.sort();
        println!(
            "per-request latency ({pass}): p50 {:.3?}  p99 {:.3?}  max {:.3?}  ({:.1} req/s)",
            percentile(&lat, 0.50),
            percentile(&lat, 0.99),
            lat.last().unwrap(),
            lines.len() as f64 / total.max(1e-12),
        );
    }

    // Weight-bounded boundary cache (ROADMAP "cache policy" item):
    // repeat optimize() rounds over the trace's surfaces — optimize
    // bypasses the plan cache, so boundary retention differences show
    // directly in the weighted hit rate ("fraction of boundary words
    // served from cache"). The 1k-slot budget admits nothing: every
    // round pays cold builds, the weighted floor of this trace.
    use mmee::config::presets;
    use mmee::search::Objective;
    let surfaces = [
        (presets::bert_base(512), presets::accel1()),
        (presets::bert_base(512), presets::accel2()),
        (presets::cc1(), presets::accel1()),
    ];
    for (label, engine) in [
        ("unbounded weight budget", MmeeEngine::native()),
        ("1k-slot weight budget", MmeeEngine::builder().boundary_weight_budget(1_000).build()),
    ] {
        let (s, n) = bench.once(&format!("optimize x2 rounds ({label})"), || {
            let mut served = 0usize;
            for _ in 0..2 {
                for (w, a) in &surfaces {
                    engine.optimize(w, a, Objective::Energy).unwrap();
                    served += 1;
                }
            }
            served
        });
        report_rates(&engine, n, s.median.as_secs_f64());
    }
    // Decode trace (dynamic shapes): an autoregressive client re-plans
    // after every generated token, so L advances by one per request and
    // NO line repeats a surface — the plan cache never hits. Serving
    // the lines pays a cold build + pass per shape; `plan_sweep` chains
    // delta builds and incumbent-seeded passes over the same shapes.
    use mmee::search::{MappingRequest, SweepSpec};
    let decode: Vec<String> = (0..16)
        .map(|i| {
            format!(
                r#"{{"workload": "bert-base", "seq": {}, "objective": "latency", "accel": "accel1"}}"#,
                512 + i
            )
        })
        .collect();
    let decode_text = decode.join("\n");
    let engine = MmeeEngine::native();
    let (line_by_line, n_dec) = bench.once("decode trace (16 steps, per-line)", || {
        let mut out = Vec::new();
        service::serve_lines(&engine, decode_text.as_bytes(), &mut out).unwrap()
    });
    report_rates(&engine, n_dec, line_by_line.median.as_secs_f64());
    let engine = MmeeEngine::native();
    let base = MappingRequest::preset("bert-base", 512, "accel1", Objective::Latency);
    let spec = SweepSpec::seq((512..528).collect());
    let (swept, _) = bench.once("decode trace (16 steps, plan_sweep)", || {
        engine.plan_sweep(&base, &spec).unwrap().plans.len()
    });
    println!(
        "    decode warm-start: plan_sweep vs per-line serving: {:.2}x",
        line_by_line.median.as_secs_f64() / swept.median.as_secs_f64().max(1e-12)
    );

    // Deadline discipline (ROADMAP "tail-latency-grade serving"): the
    // mixed trace again, now with per-request budgets — every fourth
    // line gets a zero budget (shed at admission with a structured
    // deadline_exceeded, no surface work), the rest a generous one
    // (deadline met). The met/degraded/shed split is printed so a
    // run's deadline behavior is visible at a glance.
    use mmee::util::json::Json;
    let engine = MmeeEngine::native();
    let deadlined: Vec<String> = lines
        .iter()
        .enumerate()
        .map(|(i, l)| {
            let ms = if i % 4 == 0 { 0 } else { 600_000u64 };
            format!(r#"{}, "deadline_ms": {ms}}}"#, &l[..l.len() - 1])
        })
        .collect();
    let deadline_text = deadlined.join("\n");
    let (dl, n_dl) = bench.once("serve_lines (deadline trace, cold)", || {
        let mut out = Vec::new();
        service::serve_lines(&engine, deadline_text.as_bytes(), &mut out).unwrap();
        let text = String::from_utf8(out).unwrap();
        let (mut met, mut degraded, mut shed) = (0usize, 0usize, 0usize);
        for line in text.lines() {
            let j = Json::parse(line).unwrap();
            if j.get("error").is_some() {
                shed += 1;
            } else if j.get("degraded").is_some() {
                degraded += 1;
            } else {
                met += 1;
            }
        }
        println!("    deadlines: {met} met, {degraded} degraded, {shed} shed");
        met + degraded + shed
    });
    report_rates(&engine, n_dl, dl.median.as_secs_f64());

    // Anytime degradation, forced: a 2-tile-block cancellation budget
    // against a cold engine shows how much surface an interrupted pass
    // still covers (degraded results are never memoized, so every
    // repetition pays the same partial pass).
    use mmee::coordinator::CancelToken;
    let cold_engine = MmeeEngine::native();
    let anytime_req = MappingRequest::preset("bert-base", 512, "accel1", Objective::Energy);
    let _ = bench.once("plan_cancellable (2 tile-block budget, cold)", || {
        let token = CancelToken::after_checks(2);
        let plan = cold_engine.plan_cancellable(&anytime_req, Some(&token)).unwrap();
        assert!(plan.degraded, "a 2-block budget must degrade on a cold surface");
        println!(
            "    anytime: incumbent energy {:.3e} J after {} of {} tile blocks",
            plan.solution.metrics.energy,
            plan.stats.blocks_evaluated,
            plan.stats.blocks_evaluated + plan.stats.blocks_cancelled,
        );
        1usize
    });

    println!(
        "\nbatched vs sequential (cold): {:.2}x  |  concurrent vs sequential (cold): {:.2}x",
        seq.median.as_secs_f64() / bat.median.as_secs_f64().max(1e-12),
        seq.median.as_secs_f64() / conc.median.as_secs_f64().max(1e-12),
    );
}
