//! Cluster-serving benchmark: throughput and aggregate cache-hit rate
//! vs worker count on the same mixed 3-surface preset trace as
//! `serve_throughput`, plus warm per-request latency percentiles
//! through the full front-end path (connect + hash-route + worker +
//! fan-in). Emits `BENCH_cluster.json` so the serving trajectory is
//! machine-trackable across PRs.
//!
//! `--smoke` (or `--test`) runs one 2-worker cluster on a short trace
//! of small surfaces and still writes the full JSON schema — CI runs
//! it so the schema cannot rot unnoticed.

use std::time::{Duration, Instant};

use mmee::cluster::{proto, Cluster, ClusterConfig};
use mmee::util::json::Json;

fn trace_lines(small: bool) -> Vec<String> {
    let surfaces: &[&str] = if small {
        &[
            r#""workload": "mlp", "accel": "accel1""#,
            r#""workload": "bert-base", "seq": 256, "accel": "accel1""#,
            r#""workload": "cc1", "accel": "accel1""#,
        ]
    } else {
        &[
            r#""workload": "bert-base", "seq": 512, "accel": "accel1""#,
            r#""workload": "bert-base", "seq": 512, "accel": "accel2""#,
            r#""workload": "cc1", "accel": "accel1""#,
        ]
    };
    let objectives = ["energy", "latency", "edp"];
    let n = if small { 12 } else { 24 };
    (0..n)
        .map(|i| {
            let spec = surfaces[i % surfaces.len()];
            let obj = objectives[(i / surfaces.len()) % objectives.len()];
            format!(r#"{{{spec}, "objective": "{obj}"}}"#)
        })
        .collect()
}

/// Nearest-rank percentile over an ascending-sorted sample set.
fn percentile(sorted: &[Duration], p: f64) -> Duration {
    sorted[((sorted.len() - 1) as f64 * p).round() as usize]
}

/// Aggregate (plan hits, plan misses, boundary builds) across every
/// worker, via the cluster's own `{"op": "stats"}` fan-out.
fn cache_stats(cluster: &Cluster) -> (f64, f64, f64) {
    let mut out = Vec::new();
    cluster.route(format!("{}\n", proto::STATS_LINE).as_bytes(), &mut out).expect("stats route");
    let text = String::from_utf8(out).expect("utf8");
    let j = Json::parse(text.trim()).expect("stats json");
    let workers = j
        .get("stats")
        .and_then(|s| s.get("workers"))
        .and_then(Json::as_arr)
        .expect("stats.workers");
    let (mut hits, mut misses, mut builds) = (0.0, 0.0, 0.0);
    for w in workers {
        let s = w.get("stats").expect("per-worker stats");
        let pc = s.get("plan_cache").expect("plan_cache");
        hits += pc.get("hits").and_then(Json::as_f64).unwrap_or(0.0);
        misses += pc.get("misses").and_then(Json::as_f64).unwrap_or(0.0);
        builds += s.get("boundary_builds").and_then(Json::as_f64).unwrap_or(0.0);
    }
    (hits, misses, builds)
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke" || a == "--test");
    let lines = trace_lines(smoke);
    let mut trace = lines.join("\n");
    trace.push('\n');
    let counts: &[usize] = if smoke { &[2] } else { &[1, 2, 4] };
    let program = std::path::PathBuf::from(env!("CARGO_BIN_EXE_mmee"));
    println!(
        "cluster trace: {} requests over 3 distinct surfaces; worker counts {counts:?}",
        lines.len()
    );

    let mut rows = Vec::new();
    for &workers in counts {
        let mut cfg = ClusterConfig::new(program.clone());
        cfg.workers = workers;
        cfg.worker_threads = 2;
        let t0 = Instant::now();
        let cluster = Cluster::start(cfg).expect("cluster start");
        let startup = t0.elapsed();

        let t0 = Instant::now();
        let mut out = Vec::new();
        let served = cluster.route(trace.as_bytes(), &mut out).expect("cold route");
        let cold = t0.elapsed().as_secs_f64();
        assert_eq!(served, lines.len(), "cold pass must answer the whole trace");

        let t0 = Instant::now();
        let mut out = Vec::new();
        cluster.route(trace.as_bytes(), &mut out).expect("warm route");
        let warm = t0.elapsed().as_secs_f64();

        // Warm per-request latency: one route per line, so each sample
        // pays the full client path (dispatch, connect, fan-in).
        let mut lat: Vec<Duration> = Vec::with_capacity(lines.len());
        for line in &lines {
            let mut out = Vec::new();
            let t = Instant::now();
            cluster.route(format!("{line}\n").as_bytes(), &mut out).expect("latency route");
            lat.push(t.elapsed());
        }
        lat.sort();
        let (p50, p99) = (percentile(&lat, 0.50), percentile(&lat, 0.99));

        let (hits, misses, builds) = cache_stats(&cluster);
        let plan_hit_rate = hits / (hits + misses).max(1.0);
        let restarts = cluster.total_restarts();
        cluster.shutdown();

        let req_cold = lines.len() as f64 / cold.max(1e-12);
        let req_warm = lines.len() as f64 / warm.max(1e-12);
        println!(
            "{workers} workers: startup {startup:.2?}; {req_cold:.1} req/s cold, \
             {req_warm:.1} req/s warm; plan hit rate {:.0}%; {builds:.0} boundary builds; \
             warm p50 {p50:.3?} p99 {p99:.3?}; {restarts} restarts",
            100.0 * plan_hit_rate
        );
        rows.push(Json::obj(vec![
            ("workers", Json::num(workers as f64)),
            ("req_per_s_cold", Json::num(req_cold)),
            ("req_per_s_warm", Json::num(req_warm)),
            ("plan_hit_rate", Json::num(plan_hit_rate)),
            ("boundary_builds", Json::num(builds)),
            ("p50_ms", Json::num(p50.as_secs_f64() * 1e3)),
            ("p99_ms", Json::num(p99.as_secs_f64() * 1e3)),
            ("restarts", Json::num(restarts as f64)),
        ]));
    }

    let report = Json::obj(vec![
        ("bench", Json::str("cluster_throughput")),
        ("smoke", Json::Bool(smoke)),
        ("trace_requests", Json::num(lines.len() as f64)),
        ("results", Json::arr(rows)),
    ]);
    let text = format!("{report}\n");
    for key in [
        "req_per_s_cold",
        "req_per_s_warm",
        "plan_hit_rate",
        "boundary_builds",
        "p50_ms",
        "p99_ms",
        "restarts",
    ] {
        assert!(text.contains(key), "BENCH_cluster.json schema lost key {key}");
    }
    std::fs::write("BENCH_cluster.json", &text).expect("write BENCH_cluster.json");
    println!("wrote BENCH_cluster.json{}", if smoke { "  [smoke ok]" } else { "" });
}
