//! Dynamic-shape warm-start benchmark: cold per-shape planning vs
//! `MmeeEngine::plan_sweep` (delta surface builds + incumbent-seeded
//! passes) across sequence-length sweeps — a prefill doubling series
//! (128→4096) and a decode trace (+1 steps). Also measures the
//! seeded-prune effect at the kernel level: block/pair skip counts of
//! a warm-seeded pass vs a cold pass over the same surface, with a
//! bit-identical-results assertion. Emits `BENCH_sweep.json` with the
//! amortized per-shape costs, the warm-vs-cold ratio, and a ≥2× target
//! flag, so the warm-start trajectory is machine-trackable across PRs.
//!
//! `--smoke` (or `--test`) runs a tiny sweep with a small time budget
//! and still writes the full JSON schema — CI runs it so the schema
//! (and the warm == cold equality check) cannot rot unnoticed.

use mmee::config::presets;
use mmee::encode::{build_surface, BuildConfig};
use mmee::eval::kernel::{fused_argmin3_seeded, TileConfig};
use mmee::model::Multipliers;
use mmee::search::{warm_seed, MappingRequest, MmeeEngine, Objective, SweepSpec};
use mmee::tiling::Tiling;
use mmee::util::bench::Bench;
use mmee::util::json::Json;

/// Engine with every cache disabled: each measured sweep pays its real
/// surface work instead of replaying the previous iteration's cache.
fn fresh() -> MmeeEngine {
    MmeeEngine::builder().cache_capacity(0).build()
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke" || a == "--test");
    let cases: Vec<(&str, usize, Vec<usize>)> = if smoke {
        vec![("smoke", 48, vec![48, 64, 96])]
    } else {
        vec![
            ("prefill-doubling", 128, vec![128, 256, 512, 1024, 2048, 4096]),
            ("decode-steps", 512, (512..528).collect()),
        ]
    };
    let mut bench = if smoke {
        Bench { budget: std::time::Duration::from_millis(40), ..Bench::new() }
    } else {
        Bench::new()
    };
    let accel = presets::accel1();
    let mut rows: Vec<Json> = Vec::new();
    let mut any_met = false;

    for (name, base_seq, values) in &cases {
        let n = values.len();
        let base = MappingRequest::preset("bert-base", *base_seq, "accel1", Objective::Latency);
        let shapes: Vec<_> = values
            .iter()
            .map(|&v| {
                let mut w = presets::bert_base(*base_seq);
                w.gemm.i = v;
                w.gemm.l = v;
                w
            })
            .collect();

        // Warm start must change cost, never results: check once,
        // outside the timed loops, on every preset including smoke.
        let report = fresh().plan_sweep(&base, &SweepSpec::seq(values.clone())).unwrap();
        let eref = fresh();
        for ((v, plan), w) in report.plans.iter().zip(&shapes) {
            let p = plan.as_ref().unwrap();
            let s = eref.optimize(w, &accel, Objective::Latency).unwrap();
            assert_eq!(p.solution.candidate, s.candidate, "{name} seq {v}: candidate diverged");
            assert_eq!(p.solution.tiling, s.tiling, "{name} seq {v}: tiling diverged");
            assert_eq!(p.solution.metrics.latency, s.metrics.latency, "{name} seq {v}");
        }

        let cold = bench.run(&format!("{name} cold per-shape"), || {
            let e = fresh();
            let mut acc = 0.0;
            for w in &shapes {
                acc += e.optimize(w, &accel, Objective::Latency).unwrap().metrics.latency;
            }
            acc
        });
        let warm = bench.run(&format!("{name} warm plan_sweep"), || {
            let e = fresh();
            e.plan_sweep(&base, &SweepSpec::seq(values.clone())).unwrap().plans.len()
        });
        let (cold_s, warm_s) = (cold.median.as_secs_f64(), warm.median.as_secs_f64());
        let ratio = cold_s / warm_s.max(1e-12);
        let met = ratio >= 2.0;
        any_met |= met;
        println!(
            "{name}: cold {:.1} us/shape vs warm {:.1} us/shape — {ratio:.2}x \
             (target >= 2x, met: {met})",
            cold_s * 1e6 / n as f64,
            warm_s * 1e6 / n as f64
        );
        rows.push(Json::obj(vec![
            ("preset", Json::str(*name)),
            ("shapes", Json::num(n as f64)),
            ("cold_per_shape_ns", Json::num(cold_s * 1e9 / n as f64)),
            ("warm_per_shape_ns", Json::num(warm_s * 1e9 / n as f64)),
            ("amortized_ratio", Json::num(ratio)),
            ("met", Json::Bool(met)),
        ]));
    }

    // Seeded-prune effect at the kernel level: the first case's first
    // two shapes, previous winners seeding the next surface. Skip
    // counters come from the kernel's PruneStats; results must match
    // the unseeded pass bit-for-bit.
    let (name, base_seq, values) = &cases[0];
    let q = MmeeEngine::query();
    let hw = accel.hw_vector();
    let cap = accel.capacity_words() as f64;
    let mut w1 = presets::bert_base(*base_seq);
    w1.gemm.i = values[0];
    w1.gemm.l = values[0];
    let mut w2 = presets::bert_base(*base_seq);
    w2.gemm.i = values[1];
    w2.gemm.l = values[1];
    let b1 = build_surface(&w1, &accel, Some(cap), &BuildConfig::serving());
    let b2 = build_surface(&w2, &accel, Some(cap), &BuildConfig::serving());
    let m1 = Multipliers::for_workload(&w1, &accel);
    let m2 = Multipliers::for_workload(&w2, &accel);
    let cold_seed = [f64::INFINITY; 3];
    let (best1, _) =
        fused_argmin3_seeded(q, &b1, &hw, &m1, true, TileConfig::serving(q), cold_seed);
    let winners: Vec<(usize, Tiling)> =
        best1.iter().map(|&(_, c, t)| (c, b1.tilings[t])).collect();
    let seed = warm_seed(q, &w2, &accel, &hw, &m2, cap, &winners);
    let (cold_best, cold_stats) =
        fused_argmin3_seeded(q, &b2, &hw, &m2, true, TileConfig::serving(q), cold_seed);
    let (warm_best, warm_stats) =
        fused_argmin3_seeded(q, &b2, &hw, &m2, true, TileConfig::serving(q), seed);
    assert_eq!(cold_best, warm_best, "seeded argmin diverged from unseeded");
    println!(
        "{name} seeded prune ({} -> {}): block skips {} -> {}, pair skips {} -> {} \
         over {} tiles",
        values[0],
        values[1],
        cold_stats.block_skips,
        warm_stats.block_skips,
        cold_stats.pair_skips,
        warm_stats.pair_skips,
        warm_stats.tiles
    );
    let skips = Json::obj(vec![
        ("preset", Json::str(*name)),
        ("tiles", Json::num(warm_stats.tiles as f64)),
        ("cold_block_skips", Json::num(cold_stats.block_skips as f64)),
        ("warm_block_skips", Json::num(warm_stats.block_skips as f64)),
        ("cold_pair_skips", Json::num(cold_stats.pair_skips as f64)),
        ("warm_pair_skips", Json::num(warm_stats.pair_skips as f64)),
        ("seeded_equal", Json::Bool(true)),
    ]);

    let report = Json::obj(vec![
        ("bench", Json::str("plan_sweep")),
        ("smoke", Json::Bool(smoke)),
        ("results", Json::arr(rows)),
        ("seeded_prune", skips),
        ("amortized_ratio_target", Json::num(2.0)),
        ("amortized_ratio_met", Json::Bool(any_met)),
    ]);
    let text = format!("{report}\n");
    // Schema keys are asserted on EVERY run (CI's --smoke step makes
    // the check cheap and regular; full runs get the same guarantee).
    for key in [
        "cold_per_shape_ns",
        "warm_per_shape_ns",
        "amortized_ratio",
        "seeded_prune",
        "warm_block_skips",
        "seeded_equal",
        "amortized_ratio_target",
        "amortized_ratio_met",
    ] {
        assert!(text.contains(key), "BENCH_sweep.json schema lost key {key}");
    }
    std::fs::write("BENCH_sweep.json", &text).expect("write BENCH_sweep.json");
    println!(
        "wrote BENCH_sweep.json (warm >=2x amortized target met: {any_met}){}",
        if smoke { "  [smoke ok]" } else { "" }
    );
}
