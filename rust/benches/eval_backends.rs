//! Backend runtime comparison (paper §VII-C/D runtime claims):
//! matrix-encoded evaluation (native lane kernel / XLA) vs per-mapping
//! "if-else parsing" (branchy), and — from this PR on — the fused
//! lane-major kernel vs the Block-materializing scalar path. Prints
//! mappings/second per configuration and emits a machine-readable
//! `BENCH_eval.json` (ns/point and points/s for scalar vs lane kernel,
//! argmin vs full-surface) so the perf trajectory is tracked across
//! PRs.

use mmee::config::presets;
use mmee::coordinator::parallel_chunks;
use mmee::encode::{BoundaryMatrix, QueryMatrix};
use mmee::eval::{
    branchy::BranchyBackend, kernel, native::NativeBackend, parallel_argmin3, parallel_fronts,
    xla::XlaBackend, EvalBackend, T_CHUNK,
};
use mmee::model::Multipliers;
use mmee::search::MmeeEngine;
use mmee::tiling::enumerate_tilings;
use mmee::util::bench::{Bench, Sample};
use mmee::util::json::Json;

/// One benchmark row destined for BENCH_eval.json.
fn row(name: &str, sample: &Sample, points: f64) -> Json {
    let ns = sample.median.as_secs_f64() * 1e9;
    Json::obj(vec![
        ("name", Json::str(name)),
        ("median_ns", Json::num(ns)),
        ("ns_per_point", Json::num(ns / points)),
        ("points_per_s", Json::num(points / sample.median.as_secs_f64())),
        ("points", Json::num(points)),
    ])
}

fn main() {
    let accel = presets::accel1();
    let w = presets::bert_base(512);
    let q: &QueryMatrix = MmeeEngine::query();
    let tilings = enumerate_tilings(&w.gemm, Some(accel.capacity_words() as f64));
    let b = BoundaryMatrix::build(tilings, &accel, &w);
    let hw = accel.hw_vector();
    let mult = Multipliers::for_workload(&w, &accel);
    let mappings = q.num_candidates() as f64 * b.num_tilings() as f64;
    println!(
        "surface: {} candidates x {} tilings = {:.3e} mappings",
        q.num_candidates(),
        b.num_tilings(),
        mappings
    );

    let mut bench = Bench::new();
    let mut rows: Vec<Json> = Vec::new();

    // Pre-PR scalar path: materialize 4 f32 surfaces per 64-tiling
    // chunk, then rescan them for the argmin.
    let scalar = bench.run("scalar block argmin3 (materializing)", || {
        parallel_argmin3(&NativeBackend, q, &b, &hw, &mult)
    });
    rows.push(row("scalar_block_argmin3", &scalar, mappings));

    // The serving path: fused lane kernel, bound pruning on.
    let lane = bench.run("lane kernel argmin3 (fused, pruned)", || {
        NativeBackend.argmin3(q, &b, &hw, &mult)
    });
    rows.push(row("lane_kernel_argmin3", &lane, mappings));

    let lane_noprune = bench.run("lane kernel argmin3 (fused, pruning off)", || {
        kernel::fused_argmin3(q, &b, &hw, &mult, false)
    });
    rows.push(row("lane_kernel_argmin3_noprune", &lane_noprune, mappings));

    let speedup = scalar.median.as_secs_f64() / lane.median.as_secs_f64();
    println!(
        "  scalar:      {:.3e} mappings/s",
        mappings / scalar.median.as_secs_f64()
    );
    println!(
        "  lane kernel: {:.3e} mappings/s  ({speedup:.1}x vs scalar, target >= 2x)",
        mappings / lane.median.as_secs_f64()
    );

    // Full-surface materialization (every metric for every mapping) vs
    // the fused full-surface Pareto reduction.
    let full_scalar = bench.run("scalar full-surface eval (chunked blocks)", || {
        let parts = parallel_chunks(b.num_tilings(), T_CHUNK, |lo, hi| {
            let blk =
                NativeBackend.eval_block(q, &b, &hw, &mult, (0, q.num_candidates()), (lo, hi));
            blk.energy.len()
        });
        parts.into_iter().sum::<usize>()
    });
    rows.push(row("scalar_block_full_surface", &full_scalar, mappings));

    let fronts_scalar = bench.run("scalar fronts (materializing)", || {
        parallel_fronts(&NativeBackend, q, &b, &hw, &mult)
    });
    rows.push(row("scalar_block_fronts", &fronts_scalar, mappings));

    let fronts_lane = bench.run("lane kernel fronts (fused)", || {
        kernel::fused_fronts(q, &b, &hw, &mult)
    });
    rows.push(row("lane_kernel_fronts", &fronts_lane, mappings));

    // Sanity: the fused path must report the same optima.
    let a = parallel_argmin3(&NativeBackend, q, &b, &hw, &mult);
    let k = NativeBackend.argmin3(q, &b, &hw, &mult);
    assert_eq!(a, k, "fused argmin diverged from the materializing reference");

    // Branchy is orders of magnitude slower; use a slice of the surface.
    let nt = 64.min(b.num_tilings());
    let branchy = bench.run("branchy eval (64-tiling slice)", || {
        BranchyBackend.eval_block(q, &b, &hw, &mult, (0, q.num_candidates()), (0, nt))
    });
    let branchy_points = (q.num_candidates() * nt) as f64;
    rows.push(row("branchy_block_slice", &branchy, branchy_points));
    let branchy_rate = branchy_points / branchy.median.as_secs_f64();
    println!("  branchy: {branchy_rate:.3e} mappings/s");
    println!(
        "  => matrix-encoded speedup vs per-mapping parsing: {:.0}x (paper: 64-343x)",
        mappings / lane.median.as_secs_f64() / branchy_rate
    );

    match XlaBackend::new() {
        Ok(xla) => {
            let s = bench.run("xla argmin3 (full surface, AOT artifact)", || {
                xla.argmin3(q, &b, &hw, &mult)
            });
            rows.push(row("xla_argmin3", &s, mappings));
            println!("  xla: {:.3e} mappings/s", mappings / s.median.as_secs_f64());
            // Cross-backend agreement.
            let n = NativeBackend.argmin3(q, &b, &hw, &mult);
            let x = xla.argmin3(q, &b, &hw, &mult);
            for i in 0..3 {
                let rel = (n[i].0 - x[i].0).abs() / n[i].0.max(1e-30);
                assert!(rel < 1e-3, "objective {i}: native {} vs xla {}", n[i].0, x[i].0);
            }
            println!("  native/xla argmin agreement: OK");
        }
        Err(e) => println!("  xla backend unavailable ({e}); run `make artifacts`"),
    }

    let report = Json::obj(vec![
        ("bench", Json::str("eval_backends")),
        (
            "surface",
            Json::obj(vec![
                ("workload", Json::str(w.name.clone())),
                ("accel", Json::str(accel.name.clone())),
                ("candidates", Json::num(q.num_candidates() as f64)),
                ("tilings", Json::num(b.num_tilings() as f64)),
                ("mappings", Json::num(mappings)),
            ]),
        ),
        ("results", Json::arr(rows)),
        ("argmin_speedup_lane_vs_scalar", Json::num(speedup)),
        ("argmin_speedup_target", Json::num(2.0)),
        ("argmin_speedup_met", Json::Bool(speedup >= 2.0)),
    ]);
    std::fs::write("BENCH_eval.json", format!("{report}\n")).expect("write BENCH_eval.json");
    println!("wrote BENCH_eval.json (lane-vs-scalar argmin speedup: {speedup:.2}x)");
}
