//! Backend runtime comparison (paper §VII-C/D runtime claims):
//! matrix-encoded evaluation (native / XLA) vs per-mapping "if-else
//! parsing" (branchy). Prints mappings/second per backend.

use mmee::config::presets;
use mmee::encode::{BoundaryMatrix, QueryMatrix};
use mmee::eval::{branchy::BranchyBackend, native::NativeBackend, xla::XlaBackend, EvalBackend};
use mmee::model::Multipliers;
use mmee::search::MmeeEngine;
use mmee::tiling::enumerate_tilings;
use mmee::util::bench::Bench;

fn main() {
    let accel = presets::accel1();
    let w = presets::bert_base(512);
    let q: &QueryMatrix = MmeeEngine::query();
    let tilings = enumerate_tilings(&w.gemm, Some(accel.capacity_words() as f64));
    let b = BoundaryMatrix::build(tilings, &accel, &w);
    let hw = accel.hw_vector();
    let mult = Multipliers::for_workload(&w, &accel);
    let mappings = q.num_candidates() as f64 * b.num_tilings() as f64;
    println!(
        "surface: {} candidates x {} tilings = {:.3e} mappings",
        q.num_candidates(),
        b.num_tilings(),
        mappings
    );

    let mut bench = Bench::new();
    let native = bench.run("native argmin3 (full surface)", || {
        NativeBackend.argmin3(q, &b, &hw, &mult)
    });
    println!(
        "  native: {:.3e} mappings/s",
        mappings / native.median.as_secs_f64()
    );

    // Branchy is orders of magnitude slower; use a slice of the surface.
    let nt = 64.min(b.num_tilings());
    let branchy = bench.run("branchy eval (64-tiling slice)", || {
        BranchyBackend.eval_block(q, &b, &hw, &mult, (0, q.num_candidates()), (0, nt))
    });
    let branchy_rate = (q.num_candidates() * nt) as f64 / branchy.median.as_secs_f64();
    println!("  branchy: {branchy_rate:.3e} mappings/s");
    println!(
        "  => matrix-encoded speedup vs per-mapping parsing: {:.0}x (paper: 64-343x)",
        mappings / native.median.as_secs_f64() / branchy_rate
    );

    match XlaBackend::new() {
        Ok(xla) => {
            let s = bench.run("xla argmin3 (full surface, AOT artifact)", || {
                xla.argmin3(q, &b, &hw, &mult)
            });
            println!("  xla: {:.3e} mappings/s", mappings / s.median.as_secs_f64());
            // Cross-backend agreement.
            let n = NativeBackend.argmin3(q, &b, &hw, &mult);
            let x = xla.argmin3(q, &b, &hw, &mult);
            for i in 0..3 {
                let rel = (n[i].0 - x[i].0).abs() / n[i].0.max(1e-30);
                assert!(rel < 1e-3, "objective {i}: native {} vs xla {}", n[i].0, x[i].0);
            }
            println!("  native/xla argmin agreement: OK");
        }
        Err(e) => println!("  xla backend unavailable ({e}); run `make artifacts`"),
    }
}
