//! Backend runtime comparison (paper §VII-C/D runtime claims):
//! matrix-encoded evaluation (native lane kernel / XLA) vs per-mapping
//! "if-else parsing" (branchy), the fused lane-major kernel vs the
//! Block-materializing scalar path, pool-cold (first pass: worker spawn
//! + workspace warmup) vs pool-warm steady state, fronts extraction
//! with dominance pruning on vs off, every dispatchable lane ISA on
//! the same surface, and the software-pipelined vs straight-line tile
//! loop. Prints mappings/second per
//! configuration and emits a machine-readable `BENCH_eval.json`
//! (ns/point and points/s per series) so the perf trajectory is tracked
//! across PRs.
//!
//! `--smoke` (or `--test`) runs every series once on a small surface
//! with a tiny time budget and still writes the full JSON schema — the
//! CI smoke step uses it so the schema cannot rot unnoticed.

use mmee::config::presets;
use mmee::coordinator::parallel_chunks;
use mmee::encode::{BoundaryMatrix, QueryMatrix};
use mmee::eval::simd::{self, Isa};
use mmee::eval::{
    branchy::BranchyBackend, kernel, native::NativeBackend, parallel_argmin3, parallel_fronts,
    xla::XlaBackend, EvalBackend, T_CHUNK,
};
use mmee::model::Multipliers;
use mmee::search::MmeeEngine;
use mmee::tiling::enumerate_tilings;
use mmee::util::bench::{Bench, Sample};
use mmee::util::json::Json;

/// One benchmark row destined for BENCH_eval.json.
fn row(name: &str, sample: &Sample, points: f64) -> Json {
    let ns = sample.median.as_secs_f64() * 1e9;
    Json::obj(vec![
        ("name", Json::str(name)),
        ("median_ns", Json::num(ns)),
        ("ns_per_point", Json::num(ns / points)),
        ("points_per_s", Json::num(points / sample.median.as_secs_f64())),
        ("points", Json::num(points)),
    ])
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke" || a == "--test");
    let accel = presets::accel1();
    let w = presets::bert_base(512);
    let small_q;
    let q: &QueryMatrix = if smoke {
        small_q =
            QueryMatrix::build(mmee::symbolic::pruned_table().candidates()[..40].to_vec());
        &small_q
    } else {
        MmeeEngine::query()
    };
    let mut tilings = enumerate_tilings(&w.gemm, Some(accel.capacity_words() as f64));
    if smoke {
        tilings.truncate(200);
    }
    let b = BoundaryMatrix::build(tilings, &accel, &w);
    let hw = accel.hw_vector();
    let mult = Multipliers::for_workload(&w, &accel);
    let mappings = q.num_candidates() as f64 * b.num_tilings() as f64;
    println!(
        "surface: {} candidates x {} tilings = {:.3e} mappings{}",
        q.num_candidates(),
        b.num_tilings(),
        mappings,
        if smoke { "  [smoke mode]" } else { "" }
    );

    let mut bench = if smoke {
        Bench { budget: std::time::Duration::from_millis(40), ..Bench::new() }
    } else {
        Bench::new()
    };
    let mut rows: Vec<Json> = Vec::new();

    // Pool-cold vs pool-warm: the very first surface pass of the
    // process pays evaluation-pool spawn + workspace warmup, so it must
    // be measured one-shot BEFORE any other parallel work touches the
    // pool. Everything after runs on warm persistent workers.
    let (cold, _) = bench.once("argmin3 first pass (pool cold: spawn + warmup)", || {
        NativeBackend.argmin3(q, &b, &hw, &mult)
    });
    rows.push(row("pool_cold_first_pass_argmin3", &cold, mappings));

    // Pre-PR scalar path: materialize 4 f32 surfaces per 64-tiling
    // chunk, then rescan them for the argmin.
    let scalar = bench.run("scalar block argmin3 (materializing)", || {
        parallel_argmin3(&NativeBackend, q, &b, &hw, &mult)
    });
    rows.push(row("scalar_block_argmin3", &scalar, mappings));

    // The serving path: fused lane kernel on the warm pool, pruning on.
    let lane = bench.run("lane kernel argmin3 (pool warm, fused, pruned)", || {
        NativeBackend.argmin3(q, &b, &hw, &mult)
    });
    rows.push(row("lane_kernel_argmin3", &lane, mappings));

    let lane_noprune = bench.run("lane kernel argmin3 (fused, pruning off)", || {
        kernel::fused_argmin3(q, &b, &hw, &mult, false)
    });
    rows.push(row("lane_kernel_argmin3_noprune", &lane_noprune, mappings));

    // The ISA ladder: force each dispatchable lane tier in turn on the
    // same surface. Every tier is bit-identical by contract, so only
    // the ns/point moves.
    let mut isa_samples: Vec<(Isa, Sample)> = Vec::new();
    for isa in simd::available() {
        simd::force(Some(isa));
        let s = bench.run(&format!("lane kernel argmin3 [isa={}]", isa.name()), || {
            kernel::fused_argmin3(q, &b, &hw, &mult, true)
        });
        rows.push(row(&format!("lane_kernel_argmin3_isa_{}", isa.name()), &s, mappings));
        isa_samples.push((isa, s));
    }
    simd::force(None);
    let isa_time = |want: Isa| {
        isa_samples.iter().find(|(i, _)| *i == want).map(|(_, s)| s.median.as_secs_f64())
    };
    let avx2_vs_unroll = match (isa_time(Isa::Unroll), isa_time(Isa::Avx2)) {
        (Some(u), Some(a)) => Some(u / a),
        _ => None,
    };
    if let Some(r) = avx2_vs_unroll {
        println!("  avx2 vs unroll: {r:.2}x (target >= 1.5x)");
    }

    // Software-pipelined vs straight-line tile loop, dispatch default
    // ISA both times (the two schedules are bit-identical).
    kernel::set_pipelined(Some(false));
    let straight = bench.run("lane kernel argmin3 (pipelining off)", || {
        kernel::fused_argmin3(q, &b, &hw, &mult, true)
    });
    rows.push(row("lane_kernel_argmin3_unpipelined", &straight, mappings));
    kernel::set_pipelined(Some(true));
    let piped = bench.run("lane kernel argmin3 (software-pipelined)", || {
        kernel::fused_argmin3(q, &b, &hw, &mult, true)
    });
    rows.push(row("lane_kernel_argmin3_pipelined", &piped, mappings));
    kernel::set_pipelined(None);
    let pipeline_speedup = straight.median.as_secs_f64() / piped.median.as_secs_f64();
    println!("  software pipelining: {pipeline_speedup:.2}x vs straight-line gather/fold");

    let speedup = scalar.median.as_secs_f64() / lane.median.as_secs_f64();
    let warm_vs_cold = cold.median.as_secs_f64() / lane.median.as_secs_f64();
    println!(
        "  scalar:      {:.3e} mappings/s",
        mappings / scalar.median.as_secs_f64()
    );
    println!(
        "  lane kernel: {:.3e} mappings/s  ({speedup:.1}x vs scalar, target >= 2x; \
         warm pass {warm_vs_cold:.1}x vs cold first pass)",
        mappings / lane.median.as_secs_f64()
    );

    // Full-surface materialization (every metric for every mapping) vs
    // the fused full-surface Pareto reduction.
    let full_scalar = bench.run("scalar full-surface eval (chunked blocks)", || {
        let parts = parallel_chunks(b.num_tilings(), T_CHUNK, |lo, hi| {
            let blk =
                NativeBackend.eval_block(q, &b, &hw, &mult, (0, q.num_candidates()), (lo, hi));
            blk.energy.len()
        });
        parts.into_iter().sum::<usize>()
    });
    rows.push(row("scalar_block_full_surface", &full_scalar, mappings));

    let fronts_scalar = bench.run("scalar fronts (materializing)", || {
        parallel_fronts(&NativeBackend, q, &b, &hw, &mult)
    });
    rows.push(row("scalar_block_fronts", &fronts_scalar, mappings));

    let fronts_lane = bench.run("lane kernel fronts (fused, no pruning)", || {
        kernel::fused_fronts(q, &b, &hw, &mult, false)
    });
    rows.push(row("lane_kernel_fronts", &fronts_lane, mappings));

    let fronts_pruned = bench.run("lane kernel fronts (fused, dominance-pruned)", || {
        kernel::fused_fronts(q, &b, &hw, &mult, true)
    });
    rows.push(row("lane_kernel_fronts_pruned", &fronts_pruned, mappings));
    let fronts_speedup =
        fronts_lane.median.as_secs_f64() / fronts_pruned.median.as_secs_f64();
    println!("  fronts dominance pruning: {fronts_speedup:.2}x vs unpruned");

    // Sanity: the fused paths must report the same optima and fronts.
    let a = parallel_argmin3(&NativeBackend, q, &b, &hw, &mult);
    let k = NativeBackend.argmin3(q, &b, &hw, &mult);
    assert_eq!(a, k, "fused argmin diverged from the materializing reference");
    let (el_p, bsda_p) = kernel::fused_fronts(q, &b, &hw, &mult, true);
    let (el_u, bsda_u) = kernel::fused_fronts(q, &b, &hw, &mult, false);
    assert_eq!(el_p.points(), el_u.points(), "pruned EL front diverged");
    assert_eq!(bsda_p.points(), bsda_u.points(), "pruned BS-DA front diverged");

    // Branchy is orders of magnitude slower; use a slice of the surface.
    let nt = 64.min(b.num_tilings());
    let branchy = bench.run("branchy eval (64-tiling slice)", || {
        BranchyBackend.eval_block(q, &b, &hw, &mult, (0, q.num_candidates()), (0, nt))
    });
    let branchy_points = (q.num_candidates() * nt) as f64;
    rows.push(row("branchy_block_slice", &branchy, branchy_points));
    let branchy_rate = branchy_points / branchy.median.as_secs_f64();
    println!("  branchy: {branchy_rate:.3e} mappings/s");
    println!(
        "  => matrix-encoded speedup vs per-mapping parsing: {:.0}x (paper: 64-343x)",
        mappings / lane.median.as_secs_f64() / branchy_rate
    );

    match XlaBackend::new() {
        Ok(xla) => {
            let s = bench.run("xla argmin3 (full surface, AOT artifact)", || {
                xla.argmin3(q, &b, &hw, &mult)
            });
            rows.push(row("xla_argmin3", &s, mappings));
            println!("  xla: {:.3e} mappings/s", mappings / s.median.as_secs_f64());
            // Cross-backend agreement.
            let n = NativeBackend.argmin3(q, &b, &hw, &mult);
            let x = xla.argmin3(q, &b, &hw, &mult);
            for i in 0..3 {
                let rel = (n[i].0 - x[i].0).abs() / n[i].0.max(1e-30);
                assert!(rel < 1e-3, "objective {i}: native {} vs xla {}", n[i].0, x[i].0);
            }
            println!("  native/xla argmin agreement: OK");
        }
        Err(e) => println!("  xla backend unavailable ({e}); run `make artifacts`"),
    }

    let report = Json::obj(vec![
        ("bench", Json::str("eval_backends")),
        ("smoke", Json::Bool(smoke)),
        (
            "surface",
            Json::obj(vec![
                ("workload", Json::str(w.name.clone())),
                ("accel", Json::str(accel.name.clone())),
                ("candidates", Json::num(q.num_candidates() as f64)),
                ("tilings", Json::num(b.num_tilings() as f64)),
                ("mappings", Json::num(mappings)),
            ]),
        ),
        ("results", Json::arr(rows)),
        ("argmin_speedup_lane_vs_scalar", Json::num(speedup)),
        ("argmin_speedup_target", Json::num(2.0)),
        ("argmin_speedup_met", Json::Bool(speedup >= 2.0)),
        ("pool_warm_vs_cold_speedup", Json::num(warm_vs_cold)),
        ("fronts_pruned_vs_unpruned_speedup", Json::num(fronts_speedup)),
        ("isa_default", Json::str(simd::active_name())),
        // `null` when the host cannot dispatch AVX2 (the target only
        // applies where the tier exists).
        ("avx2_vs_unroll_speedup", avx2_vs_unroll.map_or(Json::Null, Json::num)),
        ("avx2_vs_unroll_target", Json::num(1.5)),
        (
            "avx2_vs_unroll_met",
            avx2_vs_unroll.map_or(Json::Null, |r| Json::Bool(r >= 1.5)),
        ),
        ("pipelined_vs_straight_speedup", Json::num(pipeline_speedup)),
    ]);
    let text = format!("{report}\n");
    // Schema keys are asserted on EVERY run (CI's --smoke step makes
    // the check cheap and regular; full runs get the same guarantee).
    for key in [
        "pool_cold_first_pass_argmin3",
        "lane_kernel_argmin3",
        "lane_kernel_fronts_pruned",
        "pool_warm_vs_cold_speedup",
        "fronts_pruned_vs_unpruned_speedup",
        "lane_kernel_argmin3_isa_scalar",
        "lane_kernel_argmin3_pipelined",
        "avx2_vs_unroll_speedup",
        "pipelined_vs_straight_speedup",
    ] {
        assert!(text.contains(key), "BENCH_eval.json schema lost key {key}");
    }
    std::fs::write("BENCH_eval.json", &text).expect("write BENCH_eval.json");
    println!(
        "wrote BENCH_eval.json (lane-vs-scalar argmin speedup: {speedup:.2}x){}",
        if smoke { "  [smoke ok]" } else { "" }
    );
}
