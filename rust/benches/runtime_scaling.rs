//! Fig. 22 runtime scalability: MMEE optimization wall-time vs sequence
//! length (log-log power fit). `cargo bench --bench runtime_scaling`.

use mmee::config::presets;
use mmee::search::MmeeEngine;
use mmee::util::stats;

fn main() {
    let engine = MmeeEngine::native();
    let accel = presets::accel1();
    let max_seq: usize = std::env::var("MMEE_MAX_SEQ")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(131072);
    // Warm the offline table outside the timed region (it is shared
    // across workloads — the paper's offline/online split).
    let t0 = std::time::Instant::now();
    let _ = MmeeEngine::query();
    println!("offline table build: {:?}", t0.elapsed());

    let mut xs = Vec::new();
    let mut ys = Vec::new();
    let mut seq = 1024usize;
    println!("{:>8} {:>10} {:>14} {:>12}", "seq", "seconds", "mappings", "maps/s");
    while seq <= max_seq {
        let w = presets::gpt3_13b(seq);
        let st = engine.stats_only(&w, &accel).unwrap();
        let secs = st.elapsed.as_secs_f64();
        println!(
            "{:>8} {:>10.3} {:>14.3e} {:>12.3e}",
            seq,
            secs,
            st.mappings,
            st.mappings / secs
        );
        xs.push(seq as f64);
        ys.push(secs);
        seq *= 2;
    }
    let (a, b) = stats::power_law_fit(&xs, &ys);
    println!("power fit: t(n) = {a:.3e} * n^{b:.3}  (paper: ~n^0.4, <25 s at 128K)");
}
