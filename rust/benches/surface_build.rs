//! Cold surface-construction benchmark (paper §VII-H: end-to-end
//! runtime is dominated by the enumeration side): the serial reference
//! (`enumerate_tilings` + `BoundaryMatrix::build`) vs the fused
//! builder (`encode::build_surface`) — serial and pooled, capacity
//! prefilter pruned and unpruned — per preset surface. Emits
//! `BENCH_build.json` with a per-preset fused-parallel vs
//! serial-reference speedup and a ≥2× cold-build target flag, so the
//! construction-path trajectory is machine-trackable across PRs.
//!
//! `--smoke` (or `--test`) runs every series once on small surfaces
//! with a tiny time budget and still writes the full JSON schema — CI
//! runs it so the schema cannot rot unnoticed.

use mmee::config::presets;
use mmee::config::{Accelerator, Workload};
use mmee::encode::{build_surface, BoundaryMatrix, BuildConfig};
use mmee::tiling::enumerate_tilings;
use mmee::util::bench::{Bench, Sample};
use mmee::util::json::Json;

/// One benchmark row destined for BENCH_build.json.
fn row(preset: &str, series: &str, sample: &Sample, tilings: usize) -> Json {
    let ns = sample.median.as_secs_f64() * 1e9;
    Json::obj(vec![
        ("preset", Json::str(preset)),
        ("series", Json::str(series)),
        ("median_ns", Json::num(ns)),
        ("ns_per_tiling", Json::num(ns / (tilings.max(1) as f64))),
        ("tilings", Json::num(tilings as f64)),
    ])
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke" || a == "--test");
    let cases: Vec<(&str, Workload, Accelerator)> = if smoke {
        vec![("bert-base-128/accel1", presets::bert_base(128), presets::accel1())]
    } else {
        vec![
            ("bert-base-512/accel1", presets::bert_base(512), presets::accel1()),
            ("bert-base-4k/accel2", presets::bert_base(4096), presets::accel2()),
            ("gpt3-13b-2k/accel2", presets::gpt3_13b(2048), presets::accel2()),
            ("cc1/accel1", presets::cc1(), presets::accel1()),
        ]
    };

    let mut bench = if smoke {
        Bench { budget: std::time::Duration::from_millis(40), ..Bench::new() }
    } else {
        Bench::new()
    };
    let mut rows: Vec<Json> = Vec::new();
    let mut speedups: Vec<Json> = Vec::new();
    let mut all_met = true;

    for (name, w, accel) in &cases {
        let cap = Some(accel.capacity_words() as f64);
        let nt = enumerate_tilings(&w.gemm, cap).len();
        println!("{name}: {nt} tilings after the capacity prefilter");

        let serial_ref = bench.run(&format!("{name} serial reference"), || {
            BoundaryMatrix::build(enumerate_tilings(&w.gemm, cap), accel, w)
        });
        rows.push(row(name, "serial_reference", &serial_ref, nt));

        let fused_serial = bench.run(&format!("{name} fused serial (pruned)"), || {
            build_surface(w, accel, cap, &BuildConfig::serial())
        });
        rows.push(row(name, "fused_serial_pruned", &fused_serial, nt));

        let fused_serial_noprune = bench.run(&format!("{name} fused serial (unpruned)"), || {
            build_surface(w, accel, cap, &BuildConfig { prune: false, pool: None })
        });
        rows.push(row(name, "fused_serial_unpruned", &fused_serial_noprune, nt));

        let serving = BuildConfig::serving();
        let fused_par = bench.run(&format!("{name} fused parallel (pruned)"), || {
            build_surface(w, accel, cap, &serving)
        });
        rows.push(row(name, "fused_parallel_pruned", &fused_par, nt));

        let fused_par_noprune = bench.run(&format!("{name} fused parallel (unpruned)"), || {
            build_surface(w, accel, cap, &BuildConfig { prune: false, pool: serving.pool })
        });
        rows.push(row(name, "fused_parallel_unpruned", &fused_par_noprune, nt));

        // Sanity: the measured paths agree bit-for-bit.
        let want = BoundaryMatrix::build(enumerate_tilings(&w.gemm, cap), accel, w);
        let got = build_surface(w, accel, cap, &serving);
        assert_eq!(got.tilings, want.tilings, "{name}: fused tiling order diverged");
        assert_eq!(got.raw(), want.raw(), "{name}: fused raw store diverged");

        let speedup = serial_ref.median.as_secs_f64() / fused_par.median.as_secs_f64().max(1e-12);
        let prune_gain = fused_serial_noprune.median.as_secs_f64()
            / fused_serial.median.as_secs_f64().max(1e-12);
        let met = speedup >= 2.0;
        all_met &= met;
        println!(
            "  fused parallel vs serial reference: {speedup:.2}x (target >= 2x, met: {met}); \
             subtree pruning (serial fill): {prune_gain:.2}x"
        );
        speedups.push(Json::obj(vec![
            ("preset", Json::str(*name)),
            ("cold_build_speedup", Json::num(speedup)),
            ("prune_speedup_serial", Json::num(prune_gain)),
            ("met", Json::Bool(met)),
        ]));
    }

    // The uncapped sweep path (Fig. 15/16) on the first case: no
    // prefilter, so this isolates the partials + parallel-fill gains.
    let (name, w, accel) = &cases[0];
    let nt_uncapped = enumerate_tilings(&w.gemm, None).len();
    let ref_uncapped = bench.run(&format!("{name} serial reference (uncapped)"), || {
        BoundaryMatrix::build(enumerate_tilings(&w.gemm, None), accel, w)
    });
    rows.push(row(name, "serial_reference_uncapped", &ref_uncapped, nt_uncapped));
    let fused_uncapped = bench.run(&format!("{name} fused parallel (uncapped)"), || {
        build_surface(w, accel, None, &BuildConfig::serving())
    });
    rows.push(row(name, "fused_parallel_uncapped", &fused_uncapped, nt_uncapped));
    println!(
        "  uncapped sweep build: {:.2}x vs serial reference",
        ref_uncapped.median.as_secs_f64() / fused_uncapped.median.as_secs_f64().max(1e-12)
    );

    let report = Json::obj(vec![
        ("bench", Json::str("surface_build")),
        ("smoke", Json::Bool(smoke)),
        ("results", Json::arr(rows)),
        ("speedups", Json::arr(speedups)),
        ("build_speedup_target", Json::num(2.0)),
        ("build_speedup_met", Json::Bool(all_met)),
    ]);
    let text = format!("{report}\n");
    // Schema keys are asserted on EVERY run (CI's --smoke step makes
    // the check cheap and regular; full runs get the same guarantee).
    for key in [
        "serial_reference",
        "fused_serial_pruned",
        "fused_serial_unpruned",
        "fused_parallel_pruned",
        "fused_parallel_unpruned",
        "fused_parallel_uncapped",
        "cold_build_speedup",
        "build_speedup_target",
        "build_speedup_met",
    ] {
        assert!(text.contains(key), "BENCH_build.json schema lost key {key}");
    }
    std::fs::write("BENCH_build.json", &text).expect("write BENCH_build.json");
    println!(
        "wrote BENCH_build.json (cold-build >=2x target met: {all_met}){}",
        if smoke { "  [smoke ok]" } else { "" }
    );
}
