//! Quickstart: build a typed `MappingRequest`, plan it, and print the
//! solution, its pseudo-nested-loop dataflow, and the energy/latency
//! breakdown plus search stats.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use mmee::{MappingRequest, MmeeEngine, Objective};

fn main() -> mmee::Result<()> {
    // BERT-Base attention (one layer, all 12 heads) on the TPU-like
    // Accel. 2 from the paper's evaluation.
    let engine = MmeeEngine::builder().build();
    let request = MappingRequest::preset("bert-base", 4096, "accel2", Objective::Energy);

    let plan = engine.plan(&request)?;
    println!("{:#}\n", plan.to_json());

    let (workload, accel) = request.resolve()?;
    println!("{}", plan.solution.render_loopnest(&workload, &accel));
    let m = &plan.solution.metrics;
    println!("energy breakdown (mJ): dram {:.3}  sram {:.3}  mac {:.3}  sfu {:.3}",
        m.e_dram * 1e3, m.e_sram * 1e3, m.e_mac * 1e3, m.e_sfu * 1e3);
    println!("latency (ms): compute {:.3}  dram {:.3}  -> {:.3}",
        m.lat_comp * 1e3, m.lat_dram * 1e3, m.latency * 1e3);
    println!("\nevaluated {:.2e} mappings in {:?} ({})",
        plan.stats.mappings, plan.stats.elapsed, plan.provenance.backend);
    Ok(())
}
