//! Quickstart: optimize one attention workload and print the solution,
//! its pseudo-nested-loop dataflow, and the energy/latency breakdown.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use mmee::config::presets;
use mmee::search::{MmeeEngine, Objective};

fn main() {
    // BERT-Base attention (one layer, all 12 heads) on the TPU-like
    // Accel. 2 from the paper's evaluation.
    let workload = presets::bert_base(4096);
    let accel = presets::accel2();

    let engine = MmeeEngine::native();
    let solution = engine.optimize(&workload, &accel, Objective::Energy);

    println!("{:#}\n", solution.to_json());
    println!("{}", solution.render_loopnest(&workload, &accel));
    let m = &solution.metrics;
    println!("energy breakdown (mJ): dram {:.3}  sram {:.3}  mac {:.3}  sfu {:.3}",
        m.e_dram * 1e3, m.e_sram * 1e3, m.e_mac * 1e3, m.e_sfu * 1e3);
    println!("latency (ms): compute {:.3}  dram {:.3}  -> {:.3}",
        m.lat_comp * 1e3, m.lat_dram * 1e3, m.latency * 1e3);
    println!("\nevaluated {:.2e} mappings in {:?} ({})",
        solution.evaluated, solution.elapsed, engine.backend_name());
}
