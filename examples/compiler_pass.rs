//! MMEE as a compiler scheduling pass (paper §VII-L): given a small
//! transformer-layer "graph" (attention + FFN pair), pick a dataflow for
//! each fusable operator pair and emit a textual schedule the backend
//! code generator would consume.
//!
//! ```sh
//! cargo run --release --example compiler_pass
//! ```

use mmee::config::presets;
use mmee::search::{MmeeEngine, Objective};

fn main() {
    let engine = MmeeEngine::native();
    let accel = presets::accel2();

    // The layer's fusable pairs, as a high-level dialect would hand them
    // to the pass: attention (softmax between the GEMMs) and the FFN.
    let seq = 2048;
    let graph = [
        presets::gpt3_6_7b_attention(seq),
        presets::gpt3_6_7b_ffn(seq),
    ];

    println!("// schedule emitted by the MMEE pass for {}", accel.name);
    for w in &graph {
        let s = engine.optimize(w, &accel, Objective::Edp);
        println!("\n// pair {}: {} mappings explored in {:?}", w.name, s.evaluated, s.elapsed);
        println!(
            "fused_pair @{} {{ order = \"{}\", tiling = \"{}\", recompute = {}, stationary = (\"{}\", \"{}\") }}",
            w.name,
            s.candidate.order.name(),
            s.tiling.name(),
            s.candidate.recompute(),
            s.candidate.sm1.name(),
            s.candidate.sm2.name(),
        );
        for line in s.render_loopnest(w, &accel).lines() {
            println!("//   {line}");
        }
    }
}
