//! MMEE as a compiler scheduling pass (paper §VII-L): given a small
//! transformer-layer "graph" (attention + FFN pair), pick a dataflow for
//! each fusable operator pair and emit a textual schedule the backend
//! code generator would consume. Each pair is one typed
//! `MappingRequest`; the pass consumes `MappingPlan`s.
//!
//! ```sh
//! cargo run --release --example compiler_pass
//! ```

use mmee::{AccelSpec, MappingRequest, MmeeEngine, Objective, WorkloadSpec};

fn main() -> mmee::Result<()> {
    let engine = MmeeEngine::builder().build();
    let accel_spec = AccelSpec::preset("accel2");
    let accel = accel_spec.resolve()?;

    // The layer's fusable pairs, as a high-level dialect would hand them
    // to the pass: attention (softmax between the GEMMs) and the FFN.
    let seq = 2048;
    let graph = [
        WorkloadSpec::preset("gpt3-6.7b", seq),
        WorkloadSpec::preset("gpt3-6.7b-ffn", seq),
    ];

    println!("// schedule emitted by the MMEE pass for {}", accel.name);
    for spec in &graph {
        let req = MappingRequest::new(spec.clone(), accel_spec.clone(), Objective::Edp);
        let plan = engine.plan(&req)?;
        let w = spec.resolve()?;
        let s = &plan.solution;
        println!(
            "\n// pair {}: {} mappings explored in {:?} ({})",
            w.name, plan.stats.mappings, plan.stats.elapsed, plan.provenance.backend
        );
        println!(
            "fused_pair @{} {{ order = \"{}\", tiling = \"{}\", recompute = {}, stationary = (\"{}\", \"{}\") }}",
            w.name,
            s.candidate.order.name(),
            s.tiling.name(),
            s.candidate.recompute(),
            s.candidate.sm1.name(),
            s.candidate.sm2.name(),
        );
        for line in s.render_loopnest(&w, &accel).lines() {
            println!("//   {line}");
        }
    }
    Ok(())
}
