//! End-to-end driver: exercises the FULL three-layer system on the
//! paper's headline workload and reports the headline metrics.
//!
//! What this proves composes (DESIGN.md §2):
//!   L1/L2 — the AOT JAX/Pallas evaluation graph, loaded from
//!           `artifacts/*.hlo.txt` and executed via PJRT (python was only
//!           involved at `make artifacts` time); requires a `pjrt`-
//!           feature build — default builds fall back to native only;
//!   L3   — offline symbolic pruning, query/boundary encoding, tiling
//!           enumeration, batched evaluation, argmin/Pareto extraction,
//!           the stage-accurate simulator cross-check, and the TileFlow
//!           baseline it must beat — all through the typed
//!           MappingRequest → MappingPlan pipeline.
//!
//! ```sh
//! make artifacts && cargo run --release --example e2e_paper_repro
//! ```

use mmee::baselines::tileflow::TileFlow;
use mmee::baselines::Mapper;
use mmee::error::MmeeError;
use mmee::eval::xla::XlaBackend;
use mmee::sim::validate::validate_mapping;
use mmee::{MappingRequest, MmeeEngine, Objective};

fn ensure(cond: bool, what: &str) -> mmee::Result<()> {
    if cond {
        Ok(())
    } else {
        Err(MmeeError::Internal(what.to_string()))
    }
}

fn main() -> mmee::Result<()> {
    println!("=== MMEE end-to-end reproduction driver ===\n");

    // --- L1/L2: the compiled evaluation graph through PJRT ------------
    let xla = match XlaBackend::new() {
        Ok(x) => {
            println!(
                "[runtime] PJRT platform: {}; artifacts: {}",
                x.rt.platform(),
                x.rt.manifest.dir.display()
            );
            Some(x)
        }
        Err(e) => {
            println!("[runtime] artifacts unavailable ({e}); falling back to native only");
            None
        }
    };

    let request = MappingRequest::preset("bert-base", 4096, "accel2", Objective::Energy);
    let (w, accel) = request.resolve()?;
    println!("\nworkload: {} on {}\n", w.name, accel.name);

    // --- L3 search: native engine ------------------------------------
    let native = MmeeEngine::builder().build();
    let p_native = native.plan(&request)?;
    let s_native = &p_native.solution;
    println!(
        "[native ] best energy {:.3} mJ / {:.3} ms  ({:.2e} mappings, {:?})",
        s_native.metrics.energy * 1e3,
        s_native.metrics.latency * 1e3,
        p_native.stats.mappings,
        p_native.stats.elapsed
    );

    // --- L3 search through the compiled L1/L2 artifact -----------------
    if xla.is_some() {
        // PJRT handles are not `Send`: the engine builds one XLA
        // backend per worker thread through the factory.
        let engine = MmeeEngine::builder()
            .backend_factory("xla", || Ok(Box::new(XlaBackend::new()?)))
            .build();
        let p_xla = engine.plan(&request)?;
        println!(
            "[xla    ] best energy {:.3} mJ / {:.3} ms  ({:?})",
            p_xla.solution.metrics.energy * 1e3,
            p_xla.solution.metrics.latency * 1e3,
            p_xla.stats.elapsed
        );
        let rel = (p_xla.solution.metrics.energy - s_native.metrics.energy).abs()
            / s_native.metrics.energy;
        ensure(rel < 1e-3, &format!("backend disagreement: {rel}"))?;
        println!("[check  ] native == xla optimum (rel err {rel:.2e})");
    }

    // --- headline comparison vs TileFlow -------------------------------
    let tf = TileFlow::default().optimize(&w, &accel, Objective::Energy)?;
    println!(
        "[tileflow] energy {:.3} mJ / {:.3} ms  ->  MMEE saves {:.0}% energy, {:.0}% latency",
        tf.metrics.energy * 1e3,
        tf.metrics.latency * 1e3,
        (1.0 - s_native.metrics.energy / tf.metrics.energy) * 100.0,
        (1.0 - s_native.metrics.latency / tf.metrics.latency) * 100.0,
    );

    // --- simulator cross-check of the winning mapping ------------------
    let small = mmee::config::Workload {
        gemm: mmee::config::FusedGemm { i: 64, k: 16, l: 64, j: 16 },
        ..w.clone()
    };
    let t = mmee::tiling::Tiling { xd: [4, 2, 4, 2], xg: [16, 8, 16, 8] };
    let v = validate_mapping(&s_native.candidate, &t, &accel, &small);
    ensure((v.da_model - v.da_sim).abs() < 1e-6, "model/sim drift")?;
    println!(
        "[sim    ] winning dataflow executed: DA model {} == sim {} (exact)",
        v.da_model, v.da_sim
    );

    println!("\n{}", s_native.render_loopnest(&w, &accel));
    println!("=== all layers compose; see README.md for the reproduction guide ===");
    Ok(())
}
