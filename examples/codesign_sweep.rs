//! Hardware/dataflow co-design sweep — the DSE loop MMEE is built for
//! (paper §I: "dataflow mapping ... repeatedly invoked when evaluating
//! various hardware architectures"). Sweeps buffer capacity and PE-array
//! shape for a fixed workload and prints the EDP landscape.
//!
//! ```sh
//! cargo run --release --example codesign_sweep
//! ```

use mmee::config::presets;
use mmee::search::{MmeeEngine, Objective};

fn main() {
    let engine = MmeeEngine::native();
    let w = presets::gpt3_13b(2048);

    println!("== buffer-capacity sweep (32x32 PEs, GPT-3-13B @ 2K) ==");
    println!("{:>8} {:>12} {:>12} {:>14} {:>12}", "buffer", "energy(mJ)", "lat(ms)", "EDP(mJ*ms)", "DA(Mwords)");
    for kb in [64usize, 128, 256, 512, 1024, 2048, 4096] {
        let accel = presets::accel1().with_buffer_bytes(kb << 10);
        let s = engine.optimize(&w, &accel, Objective::Edp);
        println!(
            "{:>6}KB {:>12.3} {:>12.3} {:>14.4} {:>12.2}",
            kb,
            s.metrics.energy * 1e3,
            s.metrics.latency * 1e3,
            s.metrics.edp() * 1e6,
            s.metrics.da / 1e6
        );
    }

    println!("\n== PE-array shape sweep (1 MB buffer, 1024 PEs, Fig. 27 style) ==");
    println!("{:>10} {:>12} {:>12} {:>14}", "shape", "energy(mJ)", "lat(ms)", "EDP(mJ*ms)");
    for (pr, pc) in [(8usize, 128usize), (16, 64), (32, 32), (64, 16), (128, 8)] {
        let accel = presets::accel1().with_pe_shape(pr, pc);
        let s = engine.optimize(&w, &accel, Objective::Edp);
        println!(
            "{:>5}x{:<4} {:>12.3} {:>12.3} {:>14.4}",
            pr, pc,
            s.metrics.energy * 1e3,
            s.metrics.latency * 1e3,
            s.metrics.edp() * 1e6
        );
    }
}
