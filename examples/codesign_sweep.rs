//! Hardware/dataflow co-design sweep — the DSE loop MMEE is built for
//! (paper §I: "dataflow mapping ... repeatedly invoked when evaluating
//! various hardware architectures"). Sweeps buffer capacity and PE-array
//! shape for a fixed workload via inline `AccelSpec`s and prints the
//! EDP landscape. Every point is one `MappingRequest` against a shared
//! engine. Note each sweep point changes the hardware, so the sweep
//! itself is all cache misses by design — the re-query of the winning
//! configuration at the end is what lands in the plan cache, the
//! pattern of a DSE driver revisiting its best candidates.
//!
//! ```sh
//! cargo run --release --example codesign_sweep
//! ```

use mmee::{AccelSpec, MappingRequest, MmeeEngine, Objective, WorkloadSpec};

fn main() -> mmee::Result<()> {
    let engine = MmeeEngine::builder().cache_capacity(128).build();
    let workload = WorkloadSpec::preset("gpt3-13b", 2048);
    let base = AccelSpec::preset("accel1").resolve()?;

    println!("== buffer-capacity sweep (32x32 PEs, GPT-3-13B @ 2K) ==");
    println!("{:>8} {:>12} {:>12} {:>14} {:>12}", "buffer", "energy(mJ)", "lat(ms)", "EDP(mJ*ms)", "DA(Mwords)");
    for kb in [64usize, 128, 256, 512, 1024, 2048, 4096] {
        let req = MappingRequest::new(
            workload.clone(),
            AccelSpec::inline(base.with_buffer_bytes(kb << 10)),
            Objective::Edp,
        );
        match engine.plan(&req) {
            Ok(plan) => {
                let m = &plan.solution.metrics;
                println!(
                    "{:>6}KB {:>12.3} {:>12.3} {:>14.4} {:>12.2}",
                    kb,
                    m.energy * 1e3,
                    m.latency * 1e3,
                    m.edp() * 1e6,
                    m.da / 1e6
                );
            }
            // Tiny buffers may simply not fit the workload: the typed
            // error keeps the sweep going instead of aborting it.
            Err(e) => println!("{:>6}KB {:>12}", kb, format!("({})", e.kind())),
        }
    }

    println!("\n== PE-array shape sweep (1 MB buffer, 1024 PEs, Fig. 27 style) ==");
    println!("{:>10} {:>12} {:>12} {:>14}", "shape", "energy(mJ)", "lat(ms)", "EDP(mJ*ms)");
    for (pr, pc) in [(8usize, 128usize), (16, 64), (32, 32), (64, 16), (128, 8)] {
        let req = MappingRequest::new(
            workload.clone(),
            AccelSpec::inline(base.with_pe_shape(pr, pc)),
            Objective::Edp,
        );
        let plan = engine.plan(&req)?;
        let m = &plan.solution.metrics;
        println!(
            "{:>5}x{:<4} {:>12.3} {:>12.3} {:>14.4}",
            pr, pc,
            m.energy * 1e3,
            m.latency * 1e3,
            m.edp() * 1e6
        );
    }
    // A DSE driver re-examines its shortlisted configurations: the
    // repeat query is served from the plan cache without a new search.
    let revisit = MappingRequest::new(
        workload.clone(),
        AccelSpec::inline(base.with_pe_shape(32, 32)),
        Objective::Edp,
    );
    let again = engine.plan(&revisit)?;
    eprintln!(
        "revisit of 32x32: cache_hit={} in {:?}",
        again.provenance.cache_hit, again.stats.elapsed
    );
    let (hits, misses) = engine.plan_cache_stats();
    eprintln!("plan cache over the run: {hits} hits / {misses} misses");
    Ok(())
}
