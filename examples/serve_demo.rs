//! The mapper-as-a-service loop: drives `coordinator::service` with a
//! trace of requests, as an AI compiler or hardware-DSE client would.
//!
//! The trace shows the three serving shapes:
//! * single JSON-object lines (repeat queries hit the plan cache);
//! * a JSON-array **batch** line — requests sharing a resolved
//!   (workload, accel) pair are grouped into ONE surface pass, and a
//!   bad element yields an error element instead of killing the batch;
//! * the same trace through `serve_lines_concurrent`, where 4 workers
//!   share one engine and responses still come back in arrival order.
//!
//! ```sh
//! cargo run --release --example serve_demo
//! ```

use mmee::coordinator::service;
use mmee::search::MmeeEngine;

const TRACE: &str = r#"
{"workload": "bert-base", "seq": 512, "accel": "accel1", "objective": "energy"}
{"workload": "bert-base", "seq": 512, "accel": "accel1", "objective": "latency"}
{"workload": "bert-base", "seq": 512, "accel": "accel1", "objective": "energy"}
[{"workload": "gpt3-13b", "seq": 2048, "accel": "accel2", "objective": "edp"}, {"workload": "gpt3-13b", "seq": 2048, "accel": "accel2", "objective": "energy"}, {"workload": "not-a-model"}, {"workload": "gpt3-13b", "seq": 2048, "accel": "accel2", "objective": "edp"}]
{"workload": "cc1", "accel": "accel1", "objective": "energy"}
{"workload": "not-a-model", "accel": "accel1"}
"#;

fn report(engine: &MmeeEngine, label: &str, served: usize) {
    let (plan_hits, plan_misses) = engine.plan_cache_stats();
    let (b_hits, b_misses) = engine.boundary_cache_stats();
    eprintln!(
        "[{label}] served {served} mapping requests; plan cache {plan_hits}/{} hits, \
         boundary cache {b_hits}/{} hits",
        plan_hits + plan_misses,
        b_hits + b_misses,
    );
}

fn main() {
    // Sequential loop: the batch line still pays ONE surface pass for
    // its three gpt3-13b entries.
    let engine = MmeeEngine::builder().cache_capacity(64).build();
    let mut out = Vec::new();
    let served =
        service::serve_lines(&engine, TRACE.trim().as_bytes(), &mut out).unwrap();
    print!("{}", String::from_utf8(out).unwrap());
    report(&engine, "sequential", served);

    // Concurrent loop: one shared Send+Sync engine, 4 workers, responses
    // re-sequenced into arrival order.
    let engine = MmeeEngine::builder().cache_capacity(64).build();
    let mut out = Vec::new();
    let served =
        service::serve_lines_concurrent(&engine, TRACE.trim().as_bytes(), &mut out, 4)
            .unwrap();
    assert_eq!(String::from_utf8(out).unwrap().lines().count(), 6);
    report(&engine, "concurrent", served);
}
