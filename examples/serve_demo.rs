//! The mapper-as-a-service loop: drives `coordinator::service` with a
//! batch of requests, as an AI compiler or hardware-DSE client would.
//! The batch repeats a query and ends with a bad one, showing the
//! cached serving path and the structured error line (the loop never
//! panics on bad input).
//!
//! ```sh
//! cargo run --release --example serve_demo
//! ```

use mmee::coordinator::service;
use mmee::search::MmeeEngine;

fn main() {
    let engine = MmeeEngine::builder().cache_capacity(64).build();
    let requests = r#"
{"workload": "bert-base", "seq": 512, "accel": "accel1", "objective": "energy"}
{"workload": "bert-base", "seq": 512, "accel": "accel1", "objective": "latency"}
{"workload": "bert-base", "seq": 512, "accel": "accel1", "objective": "energy"}
{"workload": "gpt3-13b", "seq": 2048, "accel": "accel2", "objective": "edp"}
{"workload": "cc1", "accel": "accel1", "objective": "energy"}
{"workload": "not-a-model", "accel": "accel1"}
"#;
    let mut out = Vec::new();
    let served = service::serve_lines(&engine, requests.trim().as_bytes(), &mut out).unwrap();
    print!("{}", String::from_utf8(out).unwrap());
    let (plan_hits, plan_misses) = engine.plan_cache_stats();
    let (b_hits, b_misses) = engine.boundary_cache_stats();
    eprintln!(
        "served {served} mapping requests; plan cache {plan_hits}/{} hits, \
         boundary cache {b_hits}/{} hits",
        plan_hits + plan_misses,
        b_hits + b_misses,
    );
}
