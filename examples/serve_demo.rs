//! The mapper-as-a-service loop: drives `coordinator::service` with a
//! batch of requests, as an AI compiler or hardware-DSE client would.
//!
//! ```sh
//! cargo run --release --example serve_demo
//! ```

use mmee::coordinator::service;
use mmee::search::MmeeEngine;

fn main() {
    let engine = MmeeEngine::native();
    let requests = r#"
{"workload": "bert-base", "seq": 512, "accel": "accel1", "objective": "energy"}
{"workload": "bert-base", "seq": 4096, "accel": "accel2", "objective": "latency"}
{"workload": "gpt3-13b", "seq": 2048, "accel": "accel2", "objective": "edp"}
{"workload": "cc1", "accel": "accel1", "objective": "energy"}
"#;
    let mut out = Vec::new();
    let served = service::serve_lines(&engine, requests.trim().as_bytes(), &mut out).unwrap();
    print!("{}", String::from_utf8(out).unwrap());
    eprintln!("served {served} mapping requests");
}
