"""L1 Pallas kernel: the MMEE matrix-multiplication-encoded evaluation.

The paper's insight is that once candidate dataflows are encoded as
monomial exponent rows (query matrix Q) and tilings as log-boundary
columns (boundary matrix B), evaluating *every* (candidate, tiling) pair
is one matrix multiplication ``exp(Q . ln B)`` (paper Eq. 11).  This
kernel is that hot-spot, fused with the coefficient mask and the fixed
slot->metric segment reduction, expressed as a Pallas kernel so the whole
evaluation lowers into a single HLO module.

TPU mapping (see DESIGN.md SHardware-Adaptation): the (C*S, F) x (F, T)
contraction targets the MXU; ``exp`` and the coef scaling are VPU
element-wise post-ops in the same kernel; the segment reduction is a
static reshape-free slice-sum.  Blocking: a (bc, S, F) query block and an
(F, bt) boundary block per grid step keep the working set in VMEM.

``interpret=True`` is mandatory here: the CPU PJRT plugin cannot execute
Mosaic custom-calls, and correctness (pytest vs ref.py) plus AOT export
both run on CPU.  Real-TPU performance is estimated analytically in
DESIGN.md S9.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .. import layout

# Segment boundaries as a flat tuple so the kernel unrolls statically.
_SEGS = (
    layout.SEG_BS1, layout.SEG_BS2, layout.SEG_DA, layout.SEG_BR,
    layout.SEG_MAC, layout.SEG_SMX, layout.SEG_CL1, layout.SEG_CL2,
)


def _eval_kernel(qexp_ref, coef_ref, lnb_ref, out_ref):
    """One grid step: candidates block (bc) x tilings block (bt)."""
    bc, s, f = qexp_ref.shape
    bt = lnb_ref.shape[1]
    q = qexp_ref[...].reshape(bc * s, f)
    # MXU contraction over the feature axis, f32 accumulation.
    r = jax.lax.dot_general(
        q, lnb_ref[...],
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    # VPU post-ops: exp + coefficient mask.  coef == 0 must *disable* the
    # slot even if its exponent row would overflow exp (inf * 0 = nan), so
    # mask with a select rather than a plain multiply.
    coef = coef_ref[...][:, :, None]
    r = jnp.where(coef == 0.0, 0.0, jnp.exp(r).reshape(bc, s, bt) * coef)
    # Static slot->primitive segment sums (no gathers).
    for m, (lo, hi) in enumerate(_SEGS):
        out_ref[:, m, :] = jnp.sum(r[:, lo:hi, :], axis=1)


@functools.partial(jax.jit, static_argnames=("bc", "bt"))
def metric_primitives(qexp, coef, lnb, *, bc=64, bt=256):
    """Pallas-tiled metric-primitive evaluation.

    Args / returns: identical to ``ref.metric_primitives_ref``.
    Requires C % bc == 0 and T % bt == 0 (the AOT buckets guarantee it;
    rust pads to bucket shapes).
    """
    c, s, f = qexp.shape
    t = lnb.shape[1]
    assert c % bc == 0 and t % bt == 0, (c, t, bc, bt)
    grid = (c // bc, t // bt)
    return pl.pallas_call(
        _eval_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bc, s, f), lambda ci, ti: (ci, 0, 0)),
            pl.BlockSpec((bc, s), lambda ci, ti: (ci, 0)),
            pl.BlockSpec((f, bt), lambda ci, ti: (0, ti)),
        ],
        out_specs=pl.BlockSpec(
            (bc, layout.NUM_PRIMITIVES, bt), lambda ci, ti: (ci, 0, ti)
        ),
        out_shape=jax.ShapeDtypeStruct(
            (c, layout.NUM_PRIMITIVES, t), jnp.float32
        ),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls.
    )(qexp, coef, lnb)
