"""Pure-jnp oracle for the MMEE evaluation kernel.

This is the correctness reference for the Pallas kernel in
``mmee_eval.py``: same inputs, same outputs, no pallas, no tiling.  The
pytest suite asserts ``assert_allclose`` between the two across swept
shapes (hypothesis) and the L2 model can compose either implementation.
"""

import jax.numpy as jnp

from .. import layout


def metric_primitives_ref(qexp, coef, lnb):
    """Evaluate every (candidate, tiling) pair and segment-sum the slots.

    Args:
      qexp: f32[C, S, F] monomial exponent rows (the query matrix).
      coef: f32[C, S] per-slot scalar coefficients (0 disables a slot).
      lnb:  f32[F, T] log-boundary feature columns (the boundary matrix).

    Returns:
      f32[C, P, T] metric primitives, P = layout.NUM_PRIMITIVES, channel
      order ``layout.PRIMITIVES``.
    """
    # r[c,s,t] = coef[c,s] * exp( sum_f qexp[c,s,f] * lnb[f,t] )
    r = jnp.einsum("csf,ft->cst", qexp, lnb)
    c3 = coef[:, :, None]
    r = jnp.where(c3 == 0.0, 0.0, jnp.exp(r) * c3)
    segs = [
        layout.SEG_BS1, layout.SEG_BS2, layout.SEG_DA, layout.SEG_BR,
        layout.SEG_MAC, layout.SEG_SMX, layout.SEG_CL1, layout.SEG_CL2,
    ]
    prims = [r[:, lo:hi, :].sum(axis=1) for (lo, hi) in segs]
    return jnp.stack(prims, axis=1)


def combine_ref(prims, hw):
    """Reference metric combination (mirrors model.combine).

    Args:
      prims: f32[C, P, T] from metric_primitives_ref.
      hw: f32[NUM_HW] hardware parameter vector (layout.HW_PARAMS order).

    Returns:
      (energy, latency, da, bs), each f32[C, T].  Infeasible mappings
      (peak buffer demand > capacity) get energy = latency = layout.BIG.
    """
    bs1, bs2, da, br, mac, smx, cl1, cl2 = [prims[:, i, :] for i in range(8)]
    e_dram, e_buf, e_mac, e_sfu, e_bs, spw, spc, cap = [hw[i] for i in range(8)]
    bs = jnp.maximum(bs1, bs2)
    energy = e_dram * da + e_buf * br + e_mac * mac + e_sfu * smx + e_bs * bs
    latency = jnp.maximum((cl1 + cl2) * spc, da * spw)
    feasible = bs <= cap
    energy = jnp.where(feasible, energy, layout.BIG)
    latency = jnp.where(feasible, latency, layout.BIG)
    return energy, latency, da, bs
