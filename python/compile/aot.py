"""AOT export: lower the L2 evaluation graphs to HLO text artifacts.

Emits, per shape bucket in ``layout.BUCKETS``:

* ``mmee_full_{name}.hlo.txt``   -- full metric surfaces
* ``mmee_reduce_{name}.hlo.txt`` -- objective argmin/min reduction

plus ``manifest.json`` describing shapes, slot layout and feature order so
the rust side can verify its encoder matches (``runtime::artifacts``).

HLO *text* (not ``.serialize()``) is the interchange format: jax >= 0.5
emits HloModuleProto with 64-bit instruction ids which the xla crate's
xla_extension 0.5.1 rejects; the text parser reassigns ids and round-trips
cleanly (see /opt/xla-example/README.md).
"""

import argparse
import functools
import json
import os

import jax
from jax._src.lib import xla_client as xc

from . import layout, model


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_bucket(bucket):
    c, t, bc, bt = bucket["C"], bucket["T"], bucket["bc"], bucket["bt"]
    args = model.example_args(c, layout.NUM_SLOTS, layout.NUM_FEATURES, t)
    full = jax.jit(functools.partial(model.full_fn, bc=bc, bt=bt))
    reduce = jax.jit(functools.partial(model.reduce_fn, bc=bc, bt=bt))
    return (
        to_hlo_text(full.lower(*args)),
        to_hlo_text(reduce.lower(*args)),
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    entries = []
    for bucket in layout.BUCKETS:
        full_txt, reduce_txt = lower_bucket(bucket)
        for kind, txt in (("full", full_txt), ("reduce", reduce_txt)):
            fname = f"mmee_{kind}_{bucket['name']}.hlo.txt"
            with open(os.path.join(args.out, fname), "w") as f:
                f.write(txt)
            entries.append({
                "kind": kind,
                "bucket": bucket["name"],
                "file": fname,
                "C": bucket["C"],
                "T": bucket["T"],
                "bc": bucket["bc"],
                "bt": bucket["bt"],
            })
            print(f"wrote {fname} ({len(txt)} chars)")

    manifest = {
        "layout_version": layout.LAYOUT_VERSION,
        "num_slots": layout.NUM_SLOTS,
        "num_features": layout.NUM_FEATURES,
        "num_primitives": layout.NUM_PRIMITIVES,
        "num_hw": layout.NUM_HW,
        "features": layout.FEATURES,
        "hw_params": layout.HW_PARAMS,
        "segments": {
            "bs1": list(layout.SEG_BS1), "bs2": list(layout.SEG_BS2),
            "da": list(layout.SEG_DA), "br": list(layout.SEG_BR),
            "mac": list(layout.SEG_MAC), "smx": list(layout.SEG_SMX),
            "cl1": list(layout.SEG_CL1), "cl2": list(layout.SEG_CL2),
        },
        "big": layout.BIG,
        "artifacts": entries,
    }
    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote manifest.json ({len(entries)} artifacts)")


if __name__ == "__main__":
    main()
