"""Canonical slot/feature layout for the MMEE evaluation artifact.

This module is the *contract* between the rust encoder (L3,
``rust/src/encode/layout.rs``) and the JAX/Pallas evaluation graph (L1/L2).
Both sides hard-code the same constants; ``python/tests/test_layout.py`` and
the rust test ``encode::layout::tests`` assert they agree with the values
baked into ``artifacts/manifest.json``.

A *candidate* (one computation-ordering + buffering-level + stationary +
recompute choice) is encoded as ``NUM_SLOTS`` monomial slots.  Each slot is
an exponent row over ``NUM_FEATURES`` log-boundary features plus a scalar
coefficient; slot value = ``coef * exp(q . ln b)``.  Fixed slot ranges are
segment-summed into the metric primitives below.
"""

# ---------------------------------------------------------------- features
# Order of the boundary feature vector (log-domain).  x_D = inter-tile loop
# bound (DRAM-level tile count), x_G = granule (intra-tile) size,
# `n*_r`/`n*_c` = PE-array *block counts* ceil(x_G / P_rows|P_cols), which
# turn PE under-utilisation into monomials. `c_smx` carries the workload's
# softmax factor (1e-30 for GEMM pairs so ln stays finite).
FEATURES = [
    "i_d", "k_d", "l_d", "j_d",          # 0..3
    "i_g", "k_g", "l_g", "j_g",          # 4..7
    "ni_r",                              # 8  ceil(i_G/P_r): M-blocks, both ops
    "nk_r",                              # 9  ceil(k_G/P_r): Kr-blocks of op1
    "nl_c",                              # 10 ceil(l_G/P_c): N-blocks of op1
    "nl_r",                              # 11 ceil(l_G/P_r): Kr-blocks of op2
    "nj_c",                              # 12 ceil(j_G/P_c): N-blocks of op2
    "c_smx",                             # 13 softmax factor
    "spare1", "spare2",                  # 14..15 (always ln 1 = 0)
]
NUM_FEATURES = 16

# ------------------------------------------------------------------- slots
# Segment ranges [lo, hi) over the NUM_SLOTS axis.
SEG_BS1 = (0, 6)     # buffer size requirement of Op1 (Eq. 1): words
SEG_BS2 = (6, 12)    # buffer size requirement of Op2 (Eq. 2): words
SEG_DA = (12, 18)    # DRAM access (Eq. 7 + output spill terms): words
SEG_BR = (18, 26)    # buffer<->register-file traffic: words
SEG_MAC = (26, 28)   # MAC counts (op1 incl. recompute factor, op2)
SEG_SMX = (28, 29)   # softmax work: c_softmax * i * l (* j_D if recompute)
SEG_CL1 = (29, 30)   # op1 compute cycles (PE-padded)
SEG_CL2 = (30, 31)   # op2 compute cycles (PE-padded)
SEG_SPARE = (31, 32)
NUM_SLOTS = 32

# Metric-primitive channel order produced by the Pallas kernel.
PRIMITIVES = ["bs1", "bs2", "da", "br", "mac", "smx", "cl1", "cl2"]
NUM_PRIMITIVES = 8

# ------------------------------------------------------------ hw parameters
# Runtime scalar inputs to the compiled graph (so one artifact serves every
# accelerator config).  Units: energies J/word or J/MAC; seconds.
HW_PARAMS = [
    "e_dram",      # J per word moved DRAM<->buffer
    "e_buf",       # J per word moved buffer<->RF
    "e_mac",       # J per MAC
    "e_sfu",       # J per softmax-normalised element (c_softmax folded in Q)
    "e_bs",        # J per word-of-peak-buffer-occupancy (leakage proxy)
    "sec_per_word",  # bytes_per_word / DRAM_bandwidth
    "sec_per_cycle",  # 1 / clock frequency
    "capacity_words",  # on-chip buffer capacity in words (feasibility)
]
NUM_HW = 8

BIG = 1.0e30  # infeasible-mapping sentinel

# ------------------------------------------------------------ shape buckets
# (C, T) evaluation-bucket shapes lowered by aot.py.  C = padded candidate
# rows, T = padded tiling columns.  Rust chunks/pads to the best bucket.
BUCKETS = [
    {"name": "main", "C": 1536, "T": 512, "bc": 64, "bt": 256},
    {"name": "small", "C": 256, "T": 128, "bc": 32, "bt": 128},
]

LAYOUT_VERSION = 4
