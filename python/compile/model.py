"""L2: the MMEE evaluation graph (JAX, build-time only).

Composes the L1 Pallas kernel (``kernels.mmee_eval``) with the metric
combination and the reductions the rust search engine needs.  Two graph
variants are AOT-lowered per shape bucket:

* ``full``   -> (energy, latency, da, bs), each f32[C, T].  Feeds Pareto
  extraction and the figure harness, streamed bucket-by-bucket from rust.
* ``reduce`` -> flat argmin/min for energy-driven, latency-driven and
  EDP-driven objectives: 6 outputs
  (min_e, arg_e, min_l, arg_l, min_edp, arg_edp) with args as i32 flat
  indices into the C*T surface (rust decodes c = idx // T, t = idx % T).

Hardware parameters are *runtime inputs* (layout.HW_PARAMS) so a single
artifact serves every accelerator configuration; per-workload constant
factors (head count, array-parallel heads) are applied on the rust side.
"""

import jax.numpy as jnp

from . import layout
from .kernels import mmee_eval


def combine(prims, hw):
    """Metric combination: primitives + hw params -> (energy, latency, da, bs).

    energy  = e_dram*DA + e_buf*BR + e_mac*MAC + e_sfu*SMX + e_bs*BS   [J]
    latency = max( (CL1+CL2) * sec_per_cycle , DA * sec_per_word )     [s]
    BS      = max(BS_Op1, BS_Op2)  (paper Eq. 4), feasibility BS <= cap.
    """
    bs1 = prims[:, 0, :]
    bs2 = prims[:, 1, :]
    da = prims[:, 2, :]
    br = prims[:, 3, :]
    mac = prims[:, 4, :]
    smx = prims[:, 5, :]
    cl1 = prims[:, 6, :]
    cl2 = prims[:, 7, :]
    e_dram, e_buf, e_mac, e_sfu, e_bs, spw, spc, cap = [hw[i] for i in range(8)]
    bs = jnp.maximum(bs1, bs2)
    energy = e_dram * da + e_buf * br + e_mac * mac + e_sfu * smx + e_bs * bs
    latency = jnp.maximum((cl1 + cl2) * spc, da * spw)
    feasible = bs <= cap
    energy = jnp.where(feasible, energy, layout.BIG)
    latency = jnp.where(feasible, latency, layout.BIG)
    return energy, latency, da, bs


def full_fn(qexp, coef, lnb, hw, *, bc, bt):
    """Full metric surfaces over the (candidate, tiling) grid."""
    prims = mmee_eval.metric_primitives(qexp, coef, lnb, bc=bc, bt=bt)
    return combine(prims, hw)


def reduce_fn(qexp, coef, lnb, hw, *, bc, bt):
    """Objective-driven flat minima over the evaluation surface."""
    energy, latency, _, _ = full_fn(qexp, coef, lnb, hw, bc=bc, bt=bt)
    e = energy.reshape(-1)
    l = latency.reshape(-1)
    # EDP on the feasibility-masked surfaces; BIG*BIG overflows f32 to inf,
    # which argmin still orders correctly against finite values.
    edp = e * l
    arg_e = jnp.argmin(e).astype(jnp.int32)
    arg_l = jnp.argmin(l).astype(jnp.int32)
    arg_p = jnp.argmin(edp).astype(jnp.int32)
    return e[arg_e], arg_e, l[arg_l], arg_l, edp[arg_p], arg_p


def example_args(c, s, f, t):
    """ShapeDtypeStructs for AOT lowering of one bucket."""
    return (
        jnp.zeros((c, s, f), jnp.float32),
        jnp.zeros((c, s), jnp.float32),
        jnp.zeros((f, t), jnp.float32),
        jnp.zeros((layout.NUM_HW,), jnp.float32),
    )
