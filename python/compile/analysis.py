"""L1 performance analysis: VMEM footprint + MXU utilisation estimates.

``interpret=True`` Pallas gives CPU-numpy timings only, which are not a
TPU proxy — so the L1 optimization loop (README §Performance) reasons
about *structure*: per-grid-step VMEM working set and MXU occupancy of
the `(bc·S, F) × (F, bt)` contraction, for candidate block shapes.

Run: ``python -m compile.analysis`` (prints the block-shape table the
bucket choices in layout.py are based on).
"""

from dataclasses import dataclass

from . import layout

MXU_DIM = 128          # TPU systolic array edge
VMEM_BYTES = 16 << 20  # ~16 MiB/core class
F32 = 4


@dataclass
class BlockEstimate:
    bc: int
    bt: int
    vmem_bytes: int
    vmem_frac: float
    mxu_m_util: float   # rows occupancy of the (bc*S) x F x bt matmul
    mxu_k_util: float   # contraction-depth occupancy (F / MXU_DIM)
    mxu_n_util: float
    flops_per_byte: float


def estimate(bc: int, bt: int, s: int = layout.NUM_SLOTS,
             f: int = layout.NUM_FEATURES) -> BlockEstimate:
    """Static per-grid-step resource estimate for the eval kernel."""
    m = bc * s
    # VMEM working set: qexp block + coef block + lnb block + out block
    # + the (m, bt) intermediate before segment reduction.
    vmem = F32 * (bc * s * f + bc * s + f * bt
                  + bc * layout.NUM_PRIMITIVES * bt + m * bt)
    flops = 2.0 * m * f * bt + 3.0 * m * bt  # matmul + exp/coef/sum passes
    bytes_moved = F32 * (bc * s * f + f * bt + bc * layout.NUM_PRIMITIVES * bt)
    return BlockEstimate(
        bc=bc,
        bt=bt,
        vmem_bytes=vmem,
        vmem_frac=vmem / VMEM_BYTES,
        mxu_m_util=min(1.0, m / MXU_DIM) if m % MXU_DIM == 0 or m >= MXU_DIM
        else (m % MXU_DIM) / MXU_DIM,
        mxu_k_util=min(1.0, f / MXU_DIM),
        mxu_n_util=min(1.0, bt / MXU_DIM),
        flops_per_byte=flops / bytes_moved,
    )


def sweep(bcs=(8, 16, 32, 64, 128), bts=(128, 256, 512)):
    return [estimate(bc, bt) for bc in bcs for bt in bts]


def main():
    print(f"{'bc':>4} {'bt':>5} {'VMEM':>10} {'%VMEM':>7} "
          f"{'M-util':>7} {'K-util':>7} {'N-util':>7} {'F/B':>6}")
    for e in sweep():
        print(f"{e.bc:>4} {e.bt:>5} {e.vmem_bytes:>10} {e.vmem_frac:>6.1%} "
              f"{e.mxu_m_util:>6.1%} {e.mxu_k_util:>6.1%} "
              f"{e.mxu_n_util:>6.1%} {e.flops_per_byte:>6.1f}")
    chosen = estimate(64, 256)
    print(f"\nchosen main-bucket blocks (bc=64, bt=256): "
          f"{chosen.vmem_frac:.1%} VMEM, M/N occupancy "
          f"{chosen.mxu_m_util:.0%}/{chosen.mxu_n_util:.0%}; the K axis "
          f"(F={layout.NUM_FEATURES}) is the paper-structural limit — the "
          f"encoding is a thin contraction by design.")


if __name__ == "__main__":
    main()
