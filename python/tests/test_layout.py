"""Layout contract: constants here must match rust/src/encode/layout.rs.

The golden values below are duplicated on the rust side; a drift in either
place fails this test (and the rust unit test) before it can corrupt an
artifact.  If artifacts have been built, the manifest is cross-checked too.
"""

import json
import os

from compile import layout


def test_golden_layout():
    assert layout.NUM_FEATURES == 16
    assert layout.NUM_SLOTS == 32
    assert layout.NUM_PRIMITIVES == 8
    assert layout.NUM_HW == 8
    assert layout.SEG_BS1 == (0, 6)
    assert layout.SEG_BS2 == (6, 12)
    assert layout.SEG_DA == (12, 18)
    assert layout.SEG_BR == (18, 26)
    assert layout.SEG_MAC == (26, 28)
    assert layout.SEG_SMX == (28, 29)
    assert layout.SEG_CL1 == (29, 30)
    assert layout.SEG_CL2 == (30, 31)
    assert layout.FEATURES[:8] == [
        "i_d", "k_d", "l_d", "j_d", "i_g", "k_g", "l_g", "j_g"]
    assert layout.FEATURES[8:13] == ["ni_r", "nk_r", "nl_c", "nl_r", "nj_c"]
    assert layout.FEATURES[13] == "c_smx"
    assert layout.HW_PARAMS == [
        "e_dram", "e_buf", "e_mac", "e_sfu", "e_bs",
        "sec_per_word", "sec_per_cycle", "capacity_words"]
    assert layout.BIG == 1.0e30


def test_buckets_divisible():
    for b in layout.BUCKETS:
        assert b["C"] % b["bc"] == 0
        assert b["T"] % b["bt"] == 0


def test_manifest_consistency_if_built():
    path = os.path.join(os.path.dirname(__file__), "..", "..",
                        "artifacts", "manifest.json")
    if not os.path.exists(path):
        return  # artifacts not built yet; aot.py writes from layout anyway
    with open(path) as f:
        m = json.load(f)
    assert m["layout_version"] == layout.LAYOUT_VERSION
    assert m["num_slots"] == layout.NUM_SLOTS
    assert m["num_features"] == layout.NUM_FEATURES
    assert m["features"] == layout.FEATURES
    assert m["segments"]["bs1"] == list(layout.SEG_BS1)
    assert m["segments"]["cl2"] == list(layout.SEG_CL2)
    names = {(a["kind"], a["bucket"]) for a in m["artifacts"]}
    for b in layout.BUCKETS:
        assert ("full", b["name"]) in names
        assert ("reduce", b["name"]) in names
