"""L2 correctness: metric combination + reductions vs numpy composition."""

import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import layout, model
from compile.kernels import ref
from tests.test_kernel import make_inputs

HW = np.array([
    2.0e-10,   # e_dram J/word
    6.0e-12,   # e_buf J/word
    5.6e-13,   # e_mac J/MAC
    5.6e-12,   # e_sfu
    1.0e-14,   # e_bs
    2.0 / 60e9,   # sec_per_word (2B @ 60GB/s)
    1.0e-9,    # sec_per_cycle (1 GHz)
    524288.0,  # capacity words (1MB @ 2B)
], dtype=np.float32)


def numpy_combine(prims, hw):
    bs1, bs2, da, br, mac, smx, cl1, cl2 = [prims[:, i, :] for i in range(8)]
    bs = np.maximum(bs1, bs2)
    energy = hw[0]*da + hw[1]*br + hw[2]*mac + hw[3]*smx + hw[4]*bs
    latency = np.maximum((cl1 + cl2) * hw[6], da * hw[5])
    feas = bs <= hw[7]
    return (np.where(feas, energy, layout.BIG),
            np.where(feas, latency, layout.BIG), da, bs)


def test_combine_matches_numpy():
    rng = np.random.default_rng(7)
    qexp, coef, lnb = make_inputs(rng, 64, 128)
    prims = np.asarray(ref.metric_primitives_ref(
        jnp.asarray(qexp), jnp.asarray(coef), jnp.asarray(lnb)))
    got = model.combine(jnp.asarray(prims), jnp.asarray(HW))
    want = numpy_combine(prims, HW)
    for g, w in zip(got, want):
        np.testing.assert_allclose(np.asarray(g), w, rtol=1e-6)


def test_full_fn_pallas_equals_ref_path():
    rng = np.random.default_rng(9)
    qexp, coef, lnb = make_inputs(rng, 64, 256)
    got = model.full_fn(jnp.asarray(qexp), jnp.asarray(coef),
                        jnp.asarray(lnb), jnp.asarray(HW), bc=32, bt=256)
    prims = ref.metric_primitives_ref(
        jnp.asarray(qexp), jnp.asarray(coef), jnp.asarray(lnb))
    want = ref.combine_ref(prims, jnp.asarray(HW))
    for g, w in zip(got, want):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                   rtol=1e-5, atol=1e-5)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_reduce_fn_argmin_consistent(seed):
    rng = np.random.default_rng(seed)
    qexp, coef, lnb = make_inputs(rng, 32, 128)
    hw = jnp.asarray(HW)
    args = (jnp.asarray(qexp), jnp.asarray(coef), jnp.asarray(lnb), hw)
    min_e, arg_e, min_l, arg_l, min_p, arg_p = [
        np.asarray(x) for x in model.reduce_fn(*args, bc=32, bt=128)]
    energy, latency, _, _ = [np.asarray(x)
                             for x in model.full_fn(*args, bc=32, bt=128)]
    e, l = energy.reshape(-1), latency.reshape(-1)
    assert min_e == e.min() and e[arg_e] == min_e
    assert min_l == l.min() and l[arg_l] == min_l
    edp = e * l
    assert edp[arg_p] == edp.min()


def test_infeasible_mappings_masked():
    """Tilings whose BS exceeds capacity must never win the argmin."""
    c, t = 32, 128
    qexp = np.zeros((c, layout.NUM_SLOTS, layout.NUM_FEATURES), np.float32)
    coef = np.zeros((c, layout.NUM_SLOTS), np.float32)
    # slot 0 = BS1 = i_g; slot 12 (DA) = i_g so energy tracks i_g
    qexp[:, 0, 4] = 1.0
    coef[:, 0] = 1.0
    qexp[:, 12, 4] = 1.0
    coef[:, 12] = 1.0
    vals = np.ones((layout.NUM_FEATURES, t), np.float32)
    vals[4, :] = np.linspace(1.0, 1e7, t)  # i_g sweeps past capacity
    lnb = np.log(vals)
    hw = HW.copy()
    hw[7] = 1000.0  # tiny capacity
    energy, latency, da, bs = model.full_fn(
        jnp.asarray(qexp), jnp.asarray(coef), jnp.asarray(lnb),
        jnp.asarray(hw), bc=32, bt=128)
    energy = np.asarray(energy)
    bs = np.asarray(bs)
    assert np.all(energy[bs > 1000.0] == layout.BIG)
    assert np.all(energy[bs <= 1000.0] < layout.BIG)
