"""L1 correctness: Pallas kernel vs pure-jnp oracle.

The Pallas kernel is the evaluation hot-spot that every MMEE search result
flows through, so this is the core correctness signal of the python side.
Hypothesis sweeps block shapes and value ranges; fixed tests pin the AOT
bucket shapes.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import layout
from compile.kernels import mmee_eval, ref

S = layout.NUM_SLOTS
F = layout.NUM_FEATURES


def make_inputs(rng, c, t, exp_lo=0.0, exp_hi=3.0, ln_hi=6.0):
    """Random but realistic inputs: small integer exponents, ln-boundaries
    of plausible tile counts/sizes, sparse coef with sign structure."""
    qexp = rng.integers(0, 4, size=(c, S, F)).astype(np.float32)
    qexp *= rng.random((c, S, F)) < 0.3  # sparse exponent rows
    coef = rng.choice(
        np.array([0.0, 0.0, 1.0, 2.0, -1.0, 0.5], dtype=np.float32),
        size=(c, S),
    )
    lnb = (rng.random((F, t)) * ln_hi).astype(np.float32)
    return qexp, coef, lnb


@pytest.mark.parametrize("c,t,bc,bt", [
    (64, 128, 32, 128),
    (128, 256, 64, 256),
    (1536, 512, 64, 256),  # "main" AOT bucket shape
    (256, 128, 32, 128),   # "small" AOT bucket shape
])
def test_kernel_matches_ref_bucket_shapes(c, t, bc, bt):
    rng = np.random.default_rng(42 + c + t)
    qexp, coef, lnb = make_inputs(rng, c, t)
    got = mmee_eval.metric_primitives(
        jnp.asarray(qexp), jnp.asarray(coef), jnp.asarray(lnb), bc=bc, bt=bt)
    want = ref.metric_primitives_ref(
        jnp.asarray(qexp), jnp.asarray(coef), jnp.asarray(lnb))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@settings(max_examples=25, deadline=None)
@given(
    cb=st.integers(1, 4),      # candidate blocks
    tb=st.integers(1, 3),      # tiling blocks
    bc=st.sampled_from([8, 16, 32]),
    bt=st.sampled_from([128, 256]),
    seed=st.integers(0, 2**31 - 1),
)
def test_kernel_matches_ref_swept(cb, tb, bc, bt, seed):
    c, t = cb * bc, tb * bt
    rng = np.random.default_rng(seed)
    qexp, coef, lnb = make_inputs(rng, c, t)
    got = mmee_eval.metric_primitives(
        jnp.asarray(qexp), jnp.asarray(coef), jnp.asarray(lnb), bc=bc, bt=bt)
    want = ref.metric_primitives_ref(
        jnp.asarray(qexp), jnp.asarray(coef), jnp.asarray(lnb))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_kernel_monomial_semantics():
    """A single slot with known exponents must equal the closed form.

    Pin slot 0 (BS1 segment) to the paper's Fig. 11 example
    BS_A = k_D * i_G * k_G and check exp(q . ln b) reproduces it exactly.
    """
    c, t = 8, 128
    qexp = np.zeros((c, S, F), np.float32)
    coef = np.zeros((c, S), np.float32)
    # features: k_d = idx 1, i_g = idx 4, k_g = idx 5
    qexp[0, 0, 1] = 1.0
    qexp[0, 0, 4] = 1.0
    qexp[0, 0, 5] = 1.0
    coef[0, 0] = 1.0
    vals = np.zeros((F, t), np.float32)
    vals[:, :] = 1.0
    vals[1, 0], vals[4, 0], vals[5, 0] = 4.0, 32.0, 16.0  # k_D, i_G, k_G
    lnb = np.log(vals)
    out = mmee_eval.metric_primitives(
        jnp.asarray(qexp), jnp.asarray(coef), jnp.asarray(lnb), bc=8, bt=128)
    bs1 = np.asarray(out)[0, 0, 0]
    assert abs(bs1 - 4.0 * 32.0 * 16.0) < 1e-2
    # all other candidates' primitives are zero (coef = 0)
    assert np.all(np.asarray(out)[1:] == 0.0)


def test_kernel_zero_coef_disables_slot():
    rng = np.random.default_rng(0)
    qexp, coef, lnb = make_inputs(rng, 16, 128)
    coef[:] = 0.0
    out = mmee_eval.metric_primitives(
        jnp.asarray(qexp), jnp.asarray(coef), jnp.asarray(lnb), bc=16, bt=128)
    assert np.all(np.asarray(out) == 0.0)
