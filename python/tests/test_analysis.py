"""Structural checks of the L1 block-shape analysis."""

from compile import analysis, layout


def test_chosen_buckets_fit_vmem():
    for b in layout.BUCKETS:
        e = analysis.estimate(b["bc"], b["bt"])
        assert e.vmem_frac < 0.5, (b, e.vmem_frac)


def test_estimates_monotone_in_block_size():
    small = analysis.estimate(8, 128)
    big = analysis.estimate(64, 512)
    assert big.vmem_bytes > small.vmem_bytes
    assert big.flops_per_byte >= small.flops_per_byte


def test_mxu_utilisation_bounds():
    for e in analysis.sweep():
        for u in (e.mxu_m_util, e.mxu_k_util, e.mxu_n_util):
            assert 0.0 < u <= 1.0
    # The contraction depth is the structural ceiling: F=16 of 128 lanes.
    assert abs(analysis.estimate(64, 256).mxu_k_util - 16 / 128) < 1e-9
